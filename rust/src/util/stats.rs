//! Summary statistics, percentiles and CDFs for the experiment harness
//! (decision-time distributions, makespan aggregation across seeds).

/// Mean of a slice; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on the sorted data (`p` in [0,100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, p)
}

/// Percentile when data is already sorted ascending.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Online recorder of samples: summary stats + empirical CDF extraction.
/// Used to report the paper's "98% of decisions < X ms" figures (5d, 6d, 7b).
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    samples: Vec<f64>,
}

impl Recorder {
    pub fn new() -> Self {
        Recorder::default()
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn extend_from(&mut self, other: &Recorder) {
        self.samples.extend_from_slice(&other.samples);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        mean(&self.samples)
    }

    pub fn std_dev(&self) -> f64 {
        std_dev(&self.samples)
    }

    /// Smallest sample; 0 for an empty recorder (like `mean`/`percentile`
    /// — never ±inf, which would leak into reports).
    pub fn min(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample; 0 for an empty recorder (like `mean`/`percentile`).
    pub fn max(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn percentile(&self, p: f64) -> f64 {
        percentile(&self.samples, p)
    }

    /// Several percentiles off a single sort — what latency reports
    /// (p50/p95/p99) should use instead of re-sorting per call.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<f64> {
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ps.iter().map(|&p| percentile_sorted(&sorted, p)).collect()
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Empirical CDF evaluated at `points` thresholds: fraction of samples
    /// ≤ threshold.
    pub fn cdf_at(&self, points: &[f64]) -> Vec<f64> {
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        points
            .iter()
            .map(|&t| {
                let cnt = sorted.partition_point(|&x| x <= t);
                cnt as f64 / sorted.len().max(1) as f64
            })
            .collect()
    }

    /// (value, cumulative fraction) pairs at `n` evenly spaced quantiles —
    /// the series the paper plots as the decision-time CDF.
    pub fn cdf_series(&self, n: usize) -> Vec<(f64, f64)> {
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (0..=n)
            .map(|i| {
                let q = i as f64 / n as f64 * 100.0;
                (percentile_sorted(&sorted, q), q / 100.0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        let r = Recorder::new();
        assert!(r.is_empty());
        // Empty recorders report 0 everywhere, never ±inf.
        assert_eq!(r.min(), 0.0);
        assert_eq!(r.max(), 0.0);
        assert_eq!(r.mean(), 0.0);
        assert_eq!(r.percentile(98.0), 0.0);
    }

    #[test]
    fn recorder_min_max() {
        let mut r = Recorder::new();
        for x in [3.0, -1.0, 2.0] {
            r.push(x);
        }
        assert_eq!(r.min(), -1.0);
        assert_eq!(r.max(), 3.0);
    }

    #[test]
    fn percentiles_batch_matches_single_calls() {
        let mut r = Recorder::new();
        // Unsorted input with ties.
        for x in [5.0, 1.0, 3.0, 3.0, 2.0, 5.0, 4.0] {
            r.push(x);
        }
        let ps = [0.0, 25.0, 50.0, 95.0, 99.0, 100.0];
        let batch = r.percentiles(&ps);
        for (i, &p) in ps.iter().enumerate() {
            assert_eq!(batch[i], r.percentile(p), "p{p}");
        }
    }

    #[test]
    fn percentiles_edge_cases() {
        // Empty: all zeros, like `percentile`.
        let r = Recorder::new();
        assert_eq!(r.percentiles(&[50.0, 95.0, 99.0]), vec![0.0, 0.0, 0.0]);
        // Single sample: every percentile is that sample.
        let mut r = Recorder::new();
        r.push(42.0);
        assert_eq!(r.percentiles(&[0.0, 50.0, 99.0]), vec![42.0, 42.0, 42.0]);
        // All-tied input: interpolation between equal values stays exact.
        let mut r = Recorder::new();
        for _ in 0..10 {
            r.push(7.0);
        }
        assert_eq!(r.percentiles(&[10.0, 50.0, 95.0]), vec![7.0, 7.0, 7.0]);
    }

    #[test]
    fn recorder_cdf() {
        let mut r = Recorder::new();
        for i in 1..=100 {
            r.push(i as f64);
        }
        let cdf = r.cdf_at(&[0.0, 50.0, 98.0, 100.0]);
        assert_eq!(cdf, vec![0.0, 0.5, 0.98, 1.0]);
        assert!((r.percentile(98.0) - 98.02).abs() < 0.1);
    }

    #[test]
    fn cdf_series_monotone() {
        let mut r = Recorder::new();
        let mut v = 17u64;
        for _ in 0..500 {
            v = v.wrapping_mul(6364136223846793005).wrapping_add(1);
            r.push((v >> 32) as f64);
        }
        let series = r.cdf_series(20);
        for w in series.windows(2) {
            assert!(w[1].0 >= w[0].0);
            assert!(w[1].1 >= w[0].1);
        }
    }
}
