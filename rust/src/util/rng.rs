//! Deterministic pseudo-random number generation.
//!
//! A PCG-XSH-RR 64/32-style generator seeded through SplitMix64, plus the
//! distributions the simulator and workload generator need (uniform,
//! exponential, normal, Poisson-process intervals, categorical sampling).
//! Everything is reproducible from a single `u64` seed — experiment runs in
//! the paper-reproduction harness record their seeds.

/// Named sub-stream ids for [`Rng::stream`]: every subsystem that derives
/// its generator from one master experiment seed gets its own constant,
/// so streams are independent by construction instead of via ad-hoc
/// `seed + k` offsets scattered across call sites. The cluster and
/// workload values are the historical xor masks those generators always
/// used, so existing (config, seed) pairs reproduce bit-identically.
pub const STREAM_CLUSTER: u64 = 0xC1A5_7E85;
pub const STREAM_WORKLOAD: u64 = 0x7C9C_0FFE;
pub const STREAM_FAULT: u64 = 0xFA01_7B1A_C00F_F17E;
/// Per-agent exploration sampling inside one training episode (see
/// [`Rng::stream_seed`] — member `i` is the agent index).
pub const STREAM_AGENT: u64 = 0xA6E7_7A6E_5EED_0000;
/// Per-master arrival/workload sampling in the service soak harness
/// (member `i` is the master index).
pub const STREAM_SOAK: u64 = 0x50AC_7E57_0000_0001;

/// A small, fast, reproducible PRNG (PCG64-like: 128-bit LCG state with
/// xorshift-rotate output). Not cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let a = splitmix64(&mut s) as u128;
        let b = splitmix64(&mut s) as u128;
        let c = splitmix64(&mut s) as u128;
        let d = splitmix64(&mut s) as u128;
        let mut rng = Rng {
            state: (a << 64) | b,
            inc: ((c << 64) | d) | 1,
        };
        // Warm up: decorrelates close seeds.
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// An independent named stream of a master seed (see the `STREAM_*`
    /// constants). Unlike [`Rng::fork`], this is a pure function of
    /// `(master, stream_id)` — no parent-state mutation, so call order
    /// cannot silently couple two subsystems' randomness.
    pub fn stream(master: u64, stream_id: u64) -> Rng {
        Rng::new(master ^ stream_id)
    }

    /// Seed of the `i`-th member of a named stream family (per-agent /
    /// per-worker substreams of one master draw). Like [`Rng::stream`]
    /// this is a pure function of its inputs; the golden-ratio multiply
    /// spreads consecutive `i` across the seed space before SplitMix64
    /// expansion, so member streams are independent by construction
    /// instead of differing only in the low bits. Feed the result to any
    /// API that takes a `u64` seed.
    pub fn stream_seed(master: u64, stream_id: u64, i: u64) -> u64 {
        master ^ stream_id ^ i.wrapping_mul(0x9e37_79b9_7f4a_7c15)
    }

    /// The `i`-th member of a named stream family as a generator:
    /// `Rng::new(Rng::stream_seed(master, stream_id, i))`.
    pub fn stream_n(master: u64, stream_id: u64, i: u64) -> Rng {
        Rng::new(Self::stream_seed(master, stream_id, i))
    }

    /// Derive an independent child stream (for per-thread / per-episode rngs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut s = self.next_u64() ^ stream.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let a = splitmix64(&mut s) as u128;
        let b = splitmix64(&mut s) as u128;
        let c = splitmix64(&mut s) as u128;
        let d = splitmix64(&mut s) as u128;
        let mut rng = Rng {
            state: (a << 64) | b,
            inc: ((c << 64) | d) | 1,
        };
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// Next raw 64 bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire's multiply-shift with rejection for unbiasedness.
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_u(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range_f(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Exponentially distributed sample with the given mean (inter-arrival
    /// times of a Poisson process — the paper's continuous mode uses mean
    /// 45 s).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        assert!(mean > 0.0);
        let u = 1.0 - self.next_f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Pick a uniformly random element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted sample over zero-mass weights");
        let mut t = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample from a categorical distribution given by logits (softmax
    /// sampling; numerically stabilized). `mask[i] == false` excludes `i`.
    pub fn softmax_sample(&mut self, logits: &[f32], mask: &[bool], temperature: f64) -> usize {
        debug_assert_eq!(logits.len(), mask.len());
        let t = if temperature <= 0.0 { 1.0 } else { temperature };
        let mut max = f64::NEG_INFINITY;
        for (i, &l) in logits.iter().enumerate() {
            if mask[i] && (l as f64) > max {
                max = l as f64;
            }
        }
        assert!(max.is_finite(), "softmax_sample: empty mask");
        let mut weights = vec![0.0f64; logits.len()];
        for i in 0..logits.len() {
            if mask[i] {
                weights[i] = ((logits[i] as f64 - max) / t).exp();
            }
        }
        self.weighted(&weights)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn named_streams_are_independent_and_stable() {
        // Pure function of (master, stream): no ordering sensitivity.
        let mut a = Rng::stream(7, STREAM_CLUSTER);
        let mut a2 = Rng::stream(7, STREAM_CLUSTER);
        let mut b = Rng::stream(7, STREAM_WORKLOAD);
        let mut c = Rng::stream(7, STREAM_FAULT);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), a2.next_u64());
        }
        let mut a = Rng::stream(7, STREAM_CLUSTER);
        let same_ab = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        let mut a = Rng::stream(7, STREAM_CLUSTER);
        let same_ac = (0..64).filter(|_| a.next_u64() == c.next_u64()).count();
        assert_eq!(same_ab, 0);
        assert_eq!(same_ac, 0);
        // Bit-compatibility with the historical ad-hoc xor seeding.
        let mut old = Rng::new(42 ^ 0xC1A5_7E85);
        let mut new = Rng::stream(42, STREAM_CLUSTER);
        for _ in 0..16 {
            assert_eq!(old.next_u64(), new.next_u64());
        }
    }

    #[test]
    fn stream_family_members_are_independent() {
        // Same (master, stream, i) reproduces; different members diverge.
        let mut a = Rng::stream_n(7, STREAM_AGENT, 0);
        let mut a2 = Rng::stream_n(7, STREAM_AGENT, 0);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), a2.next_u64());
        }
        for i in 1..8u64 {
            let mut a = Rng::stream_n(7, STREAM_AGENT, 0);
            let mut b = Rng::stream_n(7, STREAM_AGENT, i);
            let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
            assert_eq!(same, 0, "member {i} must not echo member 0");
        }
        // stream_n is exactly Rng::new over stream_seed.
        let mut x = Rng::stream_n(9, STREAM_AGENT, 3);
        let mut y = Rng::new(Rng::stream_seed(9, STREAM_AGENT, 3));
        for _ in 0..16 {
            assert_eq!(x.next_u64(), y.next_u64());
        }
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Rng::new(3);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(13);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(45.0)).sum::<f64>() / n as f64;
        assert!((mean - 45.0).abs() < 1.5, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(19);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.2, "var={var}");
    }

    #[test]
    fn weighted_respects_mass() {
        let mut r = Rng::new(23);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..8000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.4, "ratio={ratio}");
    }

    #[test]
    fn softmax_sample_respects_mask() {
        let mut r = Rng::new(29);
        let logits = [0.0f32, 100.0, 0.0];
        let mask = [true, false, true];
        for _ in 0..100 {
            let i = r.softmax_sample(&logits, &mask, 1.0);
            assert_ne!(i, 1);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(31);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
