//! Deterministic fork–join parallelism over pre-indexed result slots.
//!
//! One helper, [`par_indexed`], shared by the experiment sweeps (PR 4's
//! `sweep_threaded`) and the trainer's rollout actors: run a closure over
//! a slice of work items with a bounded worker pool, collecting results
//! **in input order** so the caller's downstream reduction is identical
//! at any thread count. Workers pull items off a shared atomic cursor
//! (work stealing without queues) and write into their item's dedicated
//! slot, so no ordering ever depends on scheduling interleavings.

use anyhow::{bail, Result};

/// Run `f` over `items` with `threads` workers, collecting results in
/// input order (pre-indexed slots, so output order never depends on
/// worker interleaving). Fails fast: the first error stops workers from
/// starting further items (in-flight ones finish) and is returned.
///
/// `threads <= 1` (or a single item) degrades to a plain sequential map
/// on the calling thread — same results, no spawn overhead.
pub fn par_indexed<T: Sync, R: Send>(
    items: &[T],
    threads: usize,
    f: impl Fn(&T) -> Result<R> + Sync,
) -> Result<Vec<R>> {
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 {
        return items.iter().map(&f).collect();
    }
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::Mutex;
    let slots: Vec<Mutex<Option<Result<R>>>> = items.iter().map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let abort = AtomicBool::new(false);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                if abort.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                if r.is_err() {
                    abort.store(true, Ordering::Relaxed);
                }
                *slots[i].lock().expect("parallel slot lock poisoned") = Some(r);
            });
        }
    });
    let mut out = Vec::with_capacity(items.len());
    let mut first_err = None;
    let mut missing = 0usize;
    for m in slots {
        match m.into_inner().expect("parallel slot lock poisoned") {
            Some(Ok(r)) => out.push(r),
            Some(Err(e)) => {
                first_err.get_or_insert(e);
            }
            None => missing += 1,
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    if missing > 0 {
        bail!("parallel run aborted: {missing} items never ran");
    }
    Ok(out)
}

/// Resolve a thread-count setting: `0` means "all available cores".
pub fn effective_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        threads
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_keep_input_order() {
        let items: Vec<usize> = (0..64).collect();
        for threads in [1, 2, 8] {
            let out = par_indexed(&items, threads, |&i| Ok(i * 3)).unwrap();
            assert_eq!(out, items.iter().map(|i| i * 3).collect::<Vec<_>>());
        }
    }

    #[test]
    fn first_error_is_surfaced() {
        let items: Vec<usize> = (0..32).collect();
        let r = par_indexed(&items, 4, |&i| {
            if i == 7 {
                bail!("boom at {i}")
            }
            Ok(i)
        });
        assert!(r.is_err());
    }

    #[test]
    fn sequential_and_threaded_agree() {
        let items: Vec<u64> = (0..40).collect();
        let seq = par_indexed(&items, 1, |&i| Ok(i.wrapping_mul(0x9e37))).unwrap();
        let par = par_indexed(&items, 6, |&i| Ok(i.wrapping_mul(0x9e37))).unwrap();
        assert_eq!(seq, par);
    }

    #[test]
    fn effective_threads_resolves_auto() {
        assert!(effective_threads(0) >= 1);
        assert_eq!(effective_threads(3), 3);
    }
}
