//! Substrate utilities built from scratch (the offline registry carries
//! only the `xla` crate's dependency closure, so the usual ecosystem
//! crates — serde, clap, rand, criterion — are reimplemented here at the
//! scale this project needs).

pub mod cli;
pub mod json;
pub mod logging;
pub mod par;
pub mod rng;
pub mod stats;

/// Clamp helper for f64 (std's `clamp` panics on NaN bounds; ours is total).
pub fn fclamp(x: f64, lo: f64, hi: f64) -> f64 {
    if x < lo {
        lo
    } else if x > hi {
        hi
    } else {
        x
    }
}

/// Format a duration in seconds with adaptive units for human-facing logs.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2}s", s)
    } else {
        format!("{:.1}min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fclamp_basic() {
        assert_eq!(fclamp(5.0, 0.0, 1.0), 1.0);
        assert_eq!(fclamp(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(fclamp(0.5, 0.0, 1.0), 0.5);
    }

    #[test]
    fn fmt_secs_units() {
        assert!(fmt_secs(2e-9).ends_with("ns"));
        assert!(fmt_secs(2e-5).ends_with("us"));
        assert!(fmt_secs(2e-2).ends_with("ms"));
        assert!(fmt_secs(2.0).ends_with('s'));
        assert!(fmt_secs(600.0).ends_with("min"));
    }
}
