//! Tiny leveled logger. Level comes from `LACHESIS_LOG`
//! (`error|warn|info|debug|trace`, default `info`). Timestamps are relative
//! to process start to keep experiment logs diffable.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let cur = LEVEL.load(Ordering::Relaxed);
    if cur != u8::MAX {
        return cur;
    }
    let parsed = match std::env::var("LACHESIS_LOG").as_deref() {
        Ok(name) => match parse_level(name) {
            Some(l) => l,
            None => {
                // A typo'd level (e.g. LACHESIS_LOG=inof) used to fall
                // through silently to info; say so once instead.
                eprintln!(
                    "[lachesis] unrecognized LACHESIS_LOG value {name:?} \
                     (expected error|warn|info|debug|trace); defaulting to info"
                );
                Level::Info
            }
        },
        Err(_) => Level::Info,
    } as u8;
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Parse a `LACHESIS_LOG` level name. `None` for unrecognized values so
/// callers can distinguish a typo from an unset variable.
pub fn parse_level(name: &str) -> Option<Level> {
    match name {
        "error" => Some(Level::Error),
        "warn" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

/// Override the level programmatically (tests, quiet benches).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, module: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_level_accepts_every_name_and_rejects_typos() {
        assert_eq!(parse_level("error"), Some(Level::Error));
        assert_eq!(parse_level("warn"), Some(Level::Warn));
        assert_eq!(parse_level("info"), Some(Level::Info));
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("trace"), Some(Level::Trace));
        assert_eq!(parse_level("inof"), None);
        assert_eq!(parse_level("INFO"), None);
        assert_eq!(parse_level(""), None);
    }

    #[test]
    fn set_level_controls_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
