//! Tiny leveled logger. Level comes from `LACHESIS_LOG`
//! (`error|warn|info|debug|trace`, default `info`). Timestamps are relative
//! to process start to keep experiment logs diffable.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let cur = LEVEL.load(Ordering::Relaxed);
    if cur != u8::MAX {
        return cur;
    }
    let parsed = match std::env::var("LACHESIS_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Override the level programmatically (tests, quiet benches).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, module: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_level_controls_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
