//! # Lachesis
//!
//! A production-grade reproduction of *"Learning to Optimize DAG Scheduling
//! in Heterogeneous Environment"* (Luo et al., 2021): a two-phase DAG
//! scheduler that selects the next task with a graph-convolutional policy
//! network (MGNet, Decima-style three-level embeddings) trained by
//! actor–critic RL, and allocates executors with the **DEFT** heuristic
//! (earliest-finish-time with optional single-parent duplication, CPEFT).
//!
//! The crate is the L3 coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — event-driven heterogeneous cluster simulator,
//!   the full scheduler zoo (FIFO/SJF/HRRN/HighRankUp/HEFT/CPOP/TDCA/
//!   Decima-DEFT/Lachesis), the RL training loop, a plug-and-play
//!   scheduling service, and the experiment harness for every figure in
//!   the paper's evaluation.
//! * **L2 (python/compile/model.py)** — the MGNet + policy/value network
//!   in JAX, AOT-lowered to HLO text once at build time.
//! * **L1 (python/compile/kernels/gcn.py)** — the GCN message-passing hot
//!   spot as a Pallas kernel (forward and backward), called from L2.
//!
//! Python never runs on the request path: [`runtime`] loads the
//! `artifacts/*.hlo.txt` modules through the PJRT C API (`xla` crate) and
//! executes them directly from rust. The PJRT path is gated behind the
//! off-by-default `pjrt` cargo feature; offline builds use the
//! numerically identical pure-rust forward ([`policy::RustPolicy`]).
//!
//! The simulator itself is layered for heavy continuous traffic: each
//! executor is a [`sim::Timeline`] of busy intervals (append-compat by
//! default, gap-aware insertion via `ClusterConfig::sched_mode`), the
//! executable set is tracked incrementally by [`sim::Frontier`] counters,
//! and `SimState` memoizes `min_aft`, per-job remaining work/tasks and
//! cluster averages so per-decision cost no longer scales with workload
//! size.
//!
//! ## Quickstart
//!
//! ```no_run
//! use lachesis::prelude::*;
//!
//! let cluster = Cluster::heterogeneous(&ClusterConfig::default(), 7);
//! let workload = WorkloadGenerator::new(WorkloadConfig::small_batch(6), 42).generate();
//! let mut sim = Simulator::new(cluster, workload);
//! let report = sim.run(&mut HeftScheduler::new()).unwrap();
//! println!("makespan = {:.2}s", report.makespan);
//! ```

pub mod bench_util;
pub mod cluster;
pub mod config;
pub mod dag;
pub mod exp;
pub mod fault;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod policy;
pub mod rl;
pub mod runtime;
pub mod sched;
pub mod service;
pub mod sim;
pub mod util;
pub mod workload;

/// Convenient re-exports of the most commonly used types.
pub mod prelude {
    pub use crate::cluster::{Cluster, Executor};
    pub use crate::config::{
        ClusterConfig, ExperimentConfig, FaultConfig, SchedMode, TrainConfig, WorkloadConfig,
    };
    pub use crate::dag::{Job, JobId, Task, TaskId, TaskRef};
    pub use crate::fault::{FaultPlan, FaultStats};
    pub use crate::metrics::{ScheduleReport, SuiteReport};
    pub use crate::net::{DataItem, NetConfig, NetTopology, NetworkModel};
    pub use crate::policy::{PolicyNet, RustPolicy};
    pub use crate::sched::{
        CpopScheduler, DecimaScheduler, DeftAllocator, FifoScheduler, HeftScheduler,
        HighRankUpScheduler, HrrnScheduler, LachesisScheduler, RandomScheduler, Scheduler,
        SjfScheduler, TdcaScheduler,
    };
    pub use crate::sim::{Simulator, Timeline};
    pub use crate::util::rng::Rng;
    pub use crate::workload::{Workload, WorkloadGenerator};
}
