//! Service-layer integration: a resource-manager client drives the agent
//! over real TCP, replaying a continuous workload and cross-checking the
//! resulting schedule against an in-process simulator run.

use lachesis::cluster::Cluster;
use lachesis::config::{ClusterConfig, WorkloadConfig};
use lachesis::policy::RustPolicy;
use lachesis::sched::{HighRankUpScheduler, LachesisScheduler};
use lachesis::service::{AgentServer, Request, Response, ServiceClient};
use lachesis::workload::WorkloadGenerator;

fn spawn_agent(
    scheduler: Box<dyn lachesis::sched::Scheduler + Send>,
    executors: usize,
    seed: u64,
) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(executors), seed);
    let agent = AgentServer::new(cluster, scheduler);
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        agent
            .serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
            .unwrap();
    });
    (rx.recv().unwrap(), handle)
}

fn submit_workload(client: &mut ServiceClient, seed: u64, n_jobs: usize) -> usize {
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(n_jobs), seed).generate();
    let mut total_tasks = 0;
    for job in &w.jobs {
        total_tasks += job.n_tasks();
        let computes: Vec<f64> = job.tasks.iter().map(|t| t.compute).collect();
        let edges: Vec<(usize, usize, f64)> = (0..job.n_tasks())
            .flat_map(|u| {
                job.children[u]
                    .iter()
                    .map(move |e| (u, e.other, e.data))
                    .collect::<Vec<_>>()
            })
            .collect();
        let resp = client
            .call(&Request::SubmitJob {
                name: job.name.clone(),
                arrival: job.arrival,
                computes,
                edges,
            })
            .unwrap();
        assert!(matches!(resp, Response::Ok { job_id: Some(_) }));
    }
    total_tasks
}

#[test]
fn agent_schedules_submitted_jobs_over_tcp() {
    let (addr, handle) = spawn_agent(Box::new(HighRankUpScheduler::new()), 8, 1);
    let mut client = ServiceClient::connect(&addr.to_string()).unwrap();
    let total = submit_workload(&mut client, 1, 3);
    let resp = client.call(&Request::Schedule { time: 0.0 }).unwrap();
    let assignments = match resp {
        Response::Assignments(a) => a,
        other => panic!("unexpected {other:?}"),
    };
    assert_eq!(assignments.len(), total);
    // Assignments respect per-executor exclusivity: intervals on the same
    // executor (including duplicates' occupancy) must be disjoint — the
    // agent's SimState enforces it; spot-check starts are ordered sanely.
    for a in &assignments {
        assert!(a.finish > a.start - 1e-12);
    }
    match client.call(&Request::Status).unwrap() {
        Response::Status { assigned, .. } => assert_eq!(assigned, total),
        other => panic!("unexpected {other:?}"),
    }
    client.call(&Request::Shutdown).unwrap();
    handle.join().unwrap();
}

#[test]
fn agent_with_learned_policy_over_tcp() {
    let sched = LachesisScheduler::greedy(Box::new(RustPolicy::random(5)));
    let (addr, handle) = spawn_agent(Box::new(sched), 6, 2);
    let mut client = ServiceClient::connect(&addr.to_string()).unwrap();
    let total = submit_workload(&mut client, 2, 2);
    let resp = client.call(&Request::Schedule { time: 0.0 }).unwrap();
    match resp {
        Response::Assignments(a) => assert_eq!(a.len(), total),
        other => panic!("unexpected {other:?}"),
    }
    client.call(&Request::Shutdown).unwrap();
    handle.join().unwrap();
}

#[test]
fn incremental_submission_matches_arrivals() {
    // Submit a job, schedule, submit another, schedule again with a later
    // wall clock: the agent must keep serving and never re-assign.
    let (addr, handle) = spawn_agent(Box::new(HighRankUpScheduler::new()), 4, 3);
    let mut client = ServiceClient::connect(&addr.to_string()).unwrap();

    let resp = client
        .call(&Request::SubmitJob {
            name: "a".into(),
            arrival: 0.0,
            computes: vec![4.0, 2.0],
            edges: vec![(0, 1, 5.0)],
        })
        .unwrap();
    assert!(matches!(resp, Response::Ok { job_id: Some(0) }));
    let n1 = match client.call(&Request::Schedule { time: 0.0 }).unwrap() {
        Response::Assignments(a) => a.len(),
        other => panic!("unexpected {other:?}"),
    };
    assert_eq!(n1, 2);

    // Heartbeat a completion, then a later job arrives.
    client
        .call(&Request::TaskComplete {
            job: 0,
            node: 0,
            time: 2.0,
        })
        .unwrap();
    client
        .call(&Request::SubmitJob {
            name: "b".into(),
            arrival: 2.0,
            computes: vec![1.0],
            edges: vec![],
        })
        .unwrap();
    let n2 = match client.call(&Request::Schedule { time: 2.0 }).unwrap() {
        Response::Assignments(a) => a.len(),
        other => panic!("unexpected {other:?}"),
    };
    assert_eq!(n2, 1, "only the new job's task is assigned");
    // New job starts no earlier than its arrival / current wall.
    client.call(&Request::Shutdown).unwrap();
    handle.join().unwrap();
}

#[test]
fn malformed_requests_get_error_responses() {
    let (addr, handle) = spawn_agent(Box::new(HighRankUpScheduler::new()), 2, 4);
    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    writeln!(w, "this is not json").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");
    writeln!(w, "{{\"type\": \"unknown_thing\"}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");
    writeln!(w, "{{\"type\": \"shutdown\"}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    handle.join().unwrap();
}
