//! Service-layer integration: resource-manager clients drive the agent
//! over real TCP — replaying workloads, exercising the deferred-arrival
//! semantics, and checking that concurrent masters make progress and
//! produce exactly the single-client schedule (determinism under the
//! core lock).

use lachesis::cluster::Cluster;
use lachesis::config::{ClusterConfig, WorkloadConfig};
use lachesis::dag::Job;
use lachesis::policy::RustPolicy;
use lachesis::sched::{HighRankUpScheduler, LachesisScheduler};
use lachesis::service::{
    AgentServer, Assignment, Request, Response, ServiceClient, ServiceMode,
};
use lachesis::util::json::Json;
use lachesis::workload::WorkloadGenerator;
use std::sync::Arc;

fn spawn_agent(
    scheduler: Box<dyn lachesis::sched::Scheduler + Send>,
    executors: usize,
    seed: u64,
) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(executors), seed);
    let agent = AgentServer::new(cluster, scheduler);
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        agent
            .serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
            .unwrap();
    });
    (rx.recv().unwrap(), handle)
}

fn submit_job(client: &mut ServiceClient, job: &Job) {
    let computes: Vec<f64> = job.tasks.iter().map(|t| t.compute).collect();
    let edges: Vec<(usize, usize, f64)> = (0..job.n_tasks())
        .flat_map(|u| {
            job.children[u]
                .iter()
                .map(move |e| (u, e.other, e.data))
                .collect::<Vec<_>>()
        })
        .collect();
    let resp = client
        .call(&Request::SubmitJob {
            name: job.name.clone(),
            arrival: job.arrival,
            computes,
            edges,
        })
        .unwrap();
    assert!(matches!(resp, Response::Ok { job_id: Some(_) }));
}

fn submit_workload(client: &mut ServiceClient, seed: u64, n_jobs: usize) -> usize {
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(n_jobs), seed).generate();
    let mut total_tasks = 0;
    for job in &w.jobs {
        total_tasks += job.n_tasks();
        submit_job(client, job);
    }
    total_tasks
}

fn schedule_at(client: &mut ServiceClient, time: f64) -> Vec<Assignment> {
    match client.call(&Request::Schedule { time }).unwrap() {
        Response::Assignments(a) => a,
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn agent_schedules_submitted_jobs_over_tcp() {
    let (addr, handle) = spawn_agent(Box::new(HighRankUpScheduler::new()), 8, 1);
    let mut client = ServiceClient::connect(&addr.to_string()).unwrap();
    let total = submit_workload(&mut client, 1, 3);
    let assignments = schedule_at(&mut client, 0.0);
    assert_eq!(assignments.len(), total);
    // Assignments respect per-executor exclusivity: intervals on the same
    // executor (including duplicates' occupancy) must be disjoint — the
    // agent's SimState enforces it; spot-check starts are ordered sanely.
    for a in &assignments {
        assert!(a.finish > a.start - 1e-12);
    }
    match client.call(&Request::Status).unwrap() {
        Response::Status { assigned, .. } => assert_eq!(assigned, total),
        other => panic!("unexpected {other:?}"),
    }
    client.call(&Request::Shutdown).unwrap();
    handle.join().unwrap();
}

#[test]
fn agent_with_learned_policy_over_tcp() {
    let sched = LachesisScheduler::greedy(Box::new(RustPolicy::random(5)));
    let (addr, handle) = spawn_agent(Box::new(sched), 6, 2);
    let mut client = ServiceClient::connect(&addr.to_string()).unwrap();
    let total = submit_workload(&mut client, 2, 2);
    assert_eq!(schedule_at(&mut client, 0.0).len(), total);
    client.call(&Request::Shutdown).unwrap();
    handle.join().unwrap();
}

#[test]
fn incremental_submission_matches_arrivals() {
    // Submit a job, schedule, submit another, schedule again with a later
    // wall clock: the agent must keep serving and never re-assign.
    let (addr, handle) = spawn_agent(Box::new(HighRankUpScheduler::new()), 4, 3);
    let mut client = ServiceClient::connect(&addr.to_string()).unwrap();

    let resp = client
        .call(&Request::SubmitJob {
            name: "a".into(),
            arrival: 0.0,
            computes: vec![4.0, 2.0],
            edges: vec![(0, 1, 5.0)],
        })
        .unwrap();
    assert!(matches!(resp, Response::Ok { job_id: Some(0) }));
    assert_eq!(schedule_at(&mut client, 0.0).len(), 2);

    // Heartbeat a completion, then a later job arrives.
    client
        .call(&Request::TaskComplete {
            job: 0,
            node: 0,
            time: 2.0,
        })
        .unwrap();
    client
        .call(&Request::SubmitJob {
            name: "b".into(),
            arrival: 2.0,
            computes: vec![1.0],
            edges: vec![],
        })
        .unwrap();
    let n2 = schedule_at(&mut client, 2.0).len();
    assert_eq!(n2, 1, "only the new job's task is assigned");
    // New job starts no earlier than its arrival / current wall.
    client.call(&Request::Shutdown).unwrap();
    handle.join().unwrap();
}

/// Regression (deferred arrivals over the wire): a future-dated
/// submission must never be scheduled before its arrival time, while an
/// already-due job still schedules immediately.
#[test]
fn future_dated_submission_defers_over_tcp() {
    let (addr, handle) = spawn_agent(Box::new(HighRankUpScheduler::new()), 4, 6);
    let mut client = ServiceClient::connect(&addr.to_string()).unwrap();
    client
        .call(&Request::SubmitJob {
            name: "due".into(),
            arrival: 0.0,
            computes: vec![2.0, 1.0],
            edges: vec![(0, 1, 3.0)],
        })
        .unwrap();
    client
        .call(&Request::SubmitJob {
            name: "future".into(),
            arrival: 1000.0,
            computes: vec![5.0],
            edges: vec![],
        })
        .unwrap();
    let asgs = schedule_at(&mut client, 0.0);
    assert_eq!(asgs.len(), 2, "only the due job's tasks schedule at t=0");
    assert!(asgs.iter().all(|a| a.job == 0));
    match client.call(&Request::Status).unwrap() {
        Response::Status { pending, assigned, .. } => {
            assert_eq!(pending, 1);
            assert_eq!(assigned, 2);
        }
        other => panic!("unexpected {other:?}"),
    }
    // Advancing the wall clock past the arrival releases the job, and it
    // never starts before its arrival time.
    let asgs = schedule_at(&mut client, 1000.0);
    assert_eq!(asgs.len(), 1);
    assert_eq!(asgs[0].job, 1);
    assert!(asgs[0].start >= 1000.0 - 1e-9, "start={}", asgs[0].start);
    match client.call(&Request::Status).unwrap() {
        Response::Status { pending, .. } => assert_eq!(pending, 0),
        other => panic!("unexpected {other:?}"),
    }
    client.call(&Request::Shutdown).unwrap();
    handle.join().unwrap();
}

/// Two clients connected simultaneously, interleaving submit/status/
/// schedule in a fixed order, must produce exactly the assignments of a
/// single client submitting the same jobs in the same order — the core
/// lock serializes decisions, so the schedule depends only on request
/// order, not on which connection carried each request.
#[test]
fn two_clients_interleaved_match_single_client_run() {
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(4), 9).generate();

    // Reference: one client submits everything, then schedules.
    let (addr, handle) = spawn_agent(Box::new(HighRankUpScheduler::new()), 6, 9);
    let mut c = ServiceClient::connect(&addr.to_string()).unwrap();
    for job in &w.jobs {
        submit_job(&mut c, job);
    }
    let reference = schedule_at(&mut c, 0.0);
    assert!(!reference.is_empty());
    c.call(&Request::Shutdown).unwrap();
    handle.join().unwrap();

    // Same jobs, same order, but alternating between two live
    // connections with status polls interleaved from the idle peer.
    let (addr, handle) = spawn_agent(Box::new(HighRankUpScheduler::new()), 6, 9);
    let mut c1 = ServiceClient::connect(&addr.to_string()).unwrap();
    let mut c2 = ServiceClient::connect(&addr.to_string()).unwrap();
    for (i, job) in w.jobs.iter().enumerate() {
        let (submitter, idler) = if i % 2 == 0 {
            (&mut c1, &mut c2)
        } else {
            (&mut c2, &mut c1)
        };
        submit_job(submitter, job);
        assert!(matches!(
            idler.call(&Request::Status).unwrap(),
            Response::Status { .. }
        ));
    }
    let concurrent = schedule_at(&mut c2, 0.0);
    assert_eq!(reference, concurrent, "schedule must not depend on which connection asked");
    c1.call(&Request::Shutdown).unwrap();
    handle.join().unwrap();
}

/// Two masters hammering the agent from real threads: both must make
/// progress (no deadlock, every call answered) and every submitted task
/// must be assigned exactly once across the two connections.
#[test]
fn concurrent_clients_make_progress() {
    let (addr, handle) = spawn_agent(Box::new(HighRankUpScheduler::new()), 8, 11);
    let addr = addr.to_string();
    let jobs_per_client = 5usize;
    let tasks_per_job = 2usize;

    let worker = |name: char| {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut c = ServiceClient::connect(&addr).unwrap();
            let mut assigned = 0usize;
            for k in 0..jobs_per_client {
                let resp = c
                    .call(&Request::SubmitJob {
                        name: format!("{name}{k}"),
                        arrival: 0.0,
                        computes: vec![1.0, 2.0],
                        edges: vec![(0, 1, 1.0)],
                    })
                    .unwrap();
                assert!(matches!(resp, Response::Ok { job_id: Some(_) }));
                // A schedule drains everything currently executable —
                // possibly including the other client's tasks.
                match c.call(&Request::Schedule { time: 0.0 }).unwrap() {
                    Response::Assignments(a) => assigned += a.len(),
                    other => panic!("unexpected {other:?}"),
                }
                assert!(matches!(
                    c.call(&Request::Status).unwrap(),
                    Response::Status { .. }
                ));
            }
            assigned
        })
    };
    let t1 = worker('a');
    let t2 = worker('b');
    let n1 = t1.join().unwrap();
    let n2 = t2.join().unwrap();
    let total = 2 * jobs_per_client * tasks_per_job;
    assert_eq!(n1 + n2, total, "every task assigned exactly once");

    let mut c = ServiceClient::connect(&addr).unwrap();
    match c.call(&Request::Status).unwrap() {
        Response::Status { jobs, assigned, pending, .. } => {
            assert_eq!(jobs, 2 * jobs_per_client);
            assert_eq!(assigned, total);
            assert_eq!(pending, 0);
        }
        other => panic!("unexpected {other:?}"),
    }
    c.call(&Request::Shutdown).unwrap();
    handle.join().unwrap();
}

/// One connection, many requests in flight: a pipelining client writes a
/// whole stream of requests before reading a single response. Every
/// request must be answered, strictly in order — and the trailing
/// `status` must already see all of its connection's acknowledged
/// mutations (read-your-writes across the batched snapshot path).
#[test]
fn pipelined_client_many_in_flight() {
    let (addr, handle) = spawn_agent(Box::new(HighRankUpScheduler::new()), 6, 13);
    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    let n = 40usize;
    let mut payload = String::new();
    for k in 0..n {
        let req = Request::SubmitJob {
            name: format!("p{k}"),
            arrival: 0.0,
            computes: vec![1.0],
            edges: vec![],
        };
        payload.push_str(&req.to_json().to_string());
        payload.push('\n');
    }
    payload.push_str(&Request::Schedule { time: 0.0 }.to_json().to_string());
    payload.push('\n');
    payload.push_str(&Request::Status.to_json().to_string());
    payload.push('\n');
    w.write_all(payload.as_bytes()).unwrap();
    w.flush().unwrap();

    let mut line = String::new();
    let mut read_resp = |reader: &mut BufReader<std::net::TcpStream>| -> Response {
        line.clear();
        reader.read_line(&mut line).unwrap();
        Response::from_json(&Json::parse(line.trim()).unwrap()).unwrap()
    };
    for k in 0..n {
        match read_resp(&mut reader) {
            Response::Ok { job_id: Some(id) } => assert_eq!(id, k, "responses in order"),
            other => panic!("submit {k}: unexpected {other:?}"),
        }
    }
    match read_resp(&mut reader) {
        Response::Assignments(a) => assert_eq!(a.len(), n),
        other => panic!("unexpected {other:?}"),
    }
    match read_resp(&mut reader) {
        Response::Status { jobs, assigned, pending, .. } => {
            assert_eq!(jobs, n);
            assert_eq!(assigned, n, "status sees its own pipelined schedule");
            assert_eq!(pending, 0);
        }
        other => panic!("unexpected {other:?}"),
    }
    writeln!(w, "{}", Request::Shutdown.to_json().to_string()).unwrap();
    w.flush().unwrap();
    read_resp(&mut reader);
    handle.join().unwrap();
}

/// A pipelined burst mixing heartbeats (candidates for coalescing, one
/// out-of-order) with a future-dated submission: the max heartbeat time
/// must release the deferred arrival, exactly as per-request advances
/// would, and the trailing `status` must see it.
#[test]
fn pipelined_heartbeats_coalesce_and_release_arrivals() {
    let (addr, handle) = spawn_agent(Box::new(HighRankUpScheduler::new()), 2, 17);
    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    let mut payload = String::new();
    let submit = Request::SubmitJob {
        name: "deferred".into(),
        arrival: 50.0,
        computes: vec![1.0],
        edges: vec![],
    };
    payload.push_str(&submit.to_json().to_string());
    payload.push('\n');
    for t in [10.0, 5.0, 60.0] {
        let hb = Request::TaskComplete { job: 0, node: 0, time: t };
        payload.push_str(&hb.to_json().to_string());
        payload.push('\n');
    }
    payload.push_str(&Request::Status.to_json().to_string());
    payload.push('\n');
    w.write_all(payload.as_bytes()).unwrap();
    w.flush().unwrap();

    let mut line = String::new();
    let mut read_resp = |reader: &mut BufReader<std::net::TcpStream>| -> Response {
        line.clear();
        reader.read_line(&mut line).unwrap();
        Response::from_json(&Json::parse(line.trim()).unwrap()).unwrap()
    };
    assert!(matches!(
        read_resp(&mut reader),
        Response::Ok { job_id: Some(0) }
    ));
    for _ in 0..3 {
        assert!(matches!(read_resp(&mut reader), Response::Ok { job_id: None }));
    }
    match read_resp(&mut reader) {
        Response::Status { pending, executable, jobs, .. } => {
            assert_eq!(jobs, 1);
            assert_eq!(pending, 0, "heartbeat at t=60 releases the t=50 arrival");
            assert_eq!(executable, 1);
        }
        other => panic!("unexpected {other:?}"),
    }
    writeln!(w, "{}", Request::Shutdown.to_json().to_string()).unwrap();
    w.flush().unwrap();
    read_resp(&mut reader);
    handle.join().unwrap();
}

/// Replay one request script against a fresh server in `mode`, two
/// clients alternating per request, and return every response as its
/// wire JSON (byte-comparable across modes).
fn run_script(mode: ServiceMode, script: &[Request]) -> Vec<String> {
    let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(4), 21);
    let agent = AgentServer::with_mode(cluster, Box::new(HighRankUpScheduler::new()), mode);
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        agent
            .serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
            .unwrap();
    });
    let addr = rx.recv().unwrap().to_string();
    let mut clients = [
        ServiceClient::connect(&addr).unwrap(),
        ServiceClient::connect(&addr).unwrap(),
    ];
    let mut out = Vec::new();
    for (i, req) in script.iter().enumerate() {
        let resp = clients[i % 2].call(req).unwrap();
        out.push(resp.to_json().to_string());
    }
    clients[0].call(&Request::Shutdown).unwrap();
    handle.join().unwrap();
    out
}

/// Golden contract of the batched engine: an identical request stream —
/// submissions (one future-dated), schedules, heartbeats, a failure
/// report with recovery, status polls — produces byte-identical
/// responses under batching vs the serial reference, including the
/// schedule assignments, the `Recovery` counts, and every `pending`
/// field along the way.
#[test]
fn batched_matches_serial_golden_responses() {
    let script = vec![
        Request::Status,
        Request::SubmitJob {
            name: "a".into(),
            arrival: 0.0,
            computes: vec![3.0, 2.0],
            edges: vec![(0, 1, 4.0)],
        },
        Request::SubmitJob {
            name: "b".into(),
            arrival: 25.0,
            computes: vec![2.0],
            edges: vec![],
        },
        Request::Status,
        Request::Schedule { time: 0.0 },
        Request::TaskComplete { job: 0, node: 0, time: 10.0 },
        Request::Status,
        Request::TaskComplete { job: 0, node: 1, time: 30.0 },
        Request::Status,
        Request::Schedule { time: 30.0 },
        Request::ReportFailure {
            exec: 0,
            time: 31.0,
            recovery: Some(40.0),
        },
        Request::Status,
        Request::Schedule { time: 31.0 },
        Request::SubmitJob {
            name: "c".into(),
            arrival: 32.0,
            computes: vec![1.0, 1.0, 1.0],
            edges: vec![(0, 2, 1.0), (1, 2, 2.0)],
        },
        Request::Schedule { time: 45.0 },
        Request::Status,
    ];
    let serial = run_script(ServiceMode::Serial, &script);
    let batched = run_script(ServiceMode::Batched, &script);
    assert_eq!(serial.len(), batched.len());
    for (i, (s, b)) in serial.iter().zip(&batched).enumerate() {
        assert_eq!(s, b, "response {i} diverged for {:?}", script[i]);
    }
    // The script exercised the interesting responses, not just acks.
    assert!(serial.iter().any(|r| r.contains("assignments")));
    assert!(serial.iter().any(|r| r.contains("recovery")));
    assert!(serial.iter().any(|r| r.contains("pending")));
}

/// The acceptance-criteria probe: `status` must be served without the
/// core lock. Holding the lock (via `with_core`) while a fresh
/// connection issues `status` would deadlock if the read path touched
/// it; instead it must answer — and with the freshness the snapshot
/// contract promises (everything acknowledged before the lock was
/// taken).
#[test]
fn status_answers_while_core_lock_is_held() {
    let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(4), 19);
    let server = Arc::new(AgentServer::new(
        cluster,
        Box::new(HighRankUpScheduler::new()),
    ));
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = {
        let server = Arc::clone(&server);
        std::thread::spawn(move || {
            server
                .serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
                .unwrap();
        })
    };
    let addr = rx.recv().unwrap().to_string();
    let mut client = ServiceClient::connect(&addr).unwrap();
    let resp = client
        .call(&Request::SubmitJob {
            name: "locked-out".into(),
            arrival: 0.0,
            computes: vec![1.0, 2.0],
            edges: vec![(0, 1, 1.0)],
        })
        .unwrap();
    assert!(matches!(resp, Response::Ok { job_id: Some(0) }));

    server.with_core(|core| {
        // Core mutex held right here. A brand-new connection's status
        // must still be answered, and must already reflect the
        // acknowledged submission above.
        let mut probe = ServiceClient::connect(&addr).unwrap();
        match probe.call(&Request::Status).unwrap() {
            Response::Status { jobs, .. } => assert_eq!(jobs, 1),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(core.state().jobs.len(), 1);
    });

    client.call(&Request::Shutdown).unwrap();
    handle.join().unwrap();
}

#[test]
fn malformed_requests_get_error_responses() {
    let (addr, handle) = spawn_agent(Box::new(HighRankUpScheduler::new()), 2, 4);
    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    writeln!(w, "this is not json").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");
    writeln!(w, "{{\"type\": \"unknown_thing\"}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");
    writeln!(w, "{{\"type\": \"shutdown\"}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    handle.join().unwrap();
}
