//! Golden append-mode equivalence: the refactored simulator (interval
//! timelines + incremental frontier/caches) must produce *bit-identical*
//! schedules to the pre-refactor semantics — a single `exec_ready` scalar
//! per executor and full scans everywhere — for every scheduler in the
//! zoo, on seeded batch and continuous workloads.
//!
//! The pre-refactor `apply` math is replicated verbatim in [`RefModel`];
//! a tracing wrapper records every (wall, task, allocation) decision the
//! real engine makes, the reference replays them, and every booked copy
//! (executor, start, finish, duplicate flag) must match exactly — which
//! pins makespans, speedups, and utilization byte-for-byte.

use anyhow::Result;
use lachesis::cluster::Cluster;
use lachesis::config::{ClusterConfig, SchedMode, WorkloadConfig};
use lachesis::dag::{Job, TaskRef};
use lachesis::policy::RustPolicy;
use lachesis::sched::{
    CpopScheduler, DecimaScheduler, DlsScheduler, FifoScheduler, HeftScheduler,
    HighRankUpScheduler, HrrnScheduler, LachesisScheduler, RandomScheduler, Scheduler,
    SjfScheduler, TdcaScheduler,
};
use lachesis::sim::{Allocation, SimState, Simulator};
use lachesis::workload::{Workload, WorkloadGenerator};

/// Records every decision the wrapped scheduler emits, with the wall time
/// it was made at.
struct Tracing<S: Scheduler> {
    inner: S,
    log: Vec<(f64, TaskRef, Allocation)>,
}

impl<S: Scheduler> Tracing<S> {
    fn new(inner: S) -> Self {
        Tracing {
            inner,
            log: Vec::new(),
        }
    }
}

impl<S: Scheduler> Scheduler for Tracing<S> {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn reset(&mut self) {
        self.inner.reset();
        self.log.clear();
    }

    fn step(&mut self, state: &SimState) -> Result<Option<(TaskRef, Allocation)>> {
        let d = self.inner.step(state)?;
        if let Some((t, a)) = d {
            self.log.push((state.wall, t, a));
        }
        Ok(d)
    }
}

/// Verbatim replica of the pre-refactor append-only scheduling state:
/// one `exec_ready` scalar per executor, placements as (exec, finish)
/// lists, data readiness recomputed by full scans.
struct RefModel {
    cluster: Cluster,
    jobs: Vec<Job>,
    exec_ready: Vec<f64>,
    /// `placements[job][node]` — (exec, finish) per scheduled copy.
    placements: Vec<Vec<Vec<(usize, f64)>>>,
    /// Booking log per executor: (task, start, finish, duplicate).
    log: Vec<Vec<(TaskRef, f64, f64, bool)>>,
}

impl RefModel {
    fn new(cluster: Cluster, jobs: Vec<Job>) -> RefModel {
        let n_exec = cluster.len();
        RefModel {
            exec_ready: vec![0.0; n_exec],
            placements: jobs.iter().map(|j| vec![Vec::new(); j.n_tasks()]).collect(),
            log: vec![Vec::new(); n_exec],
            cluster,
            jobs,
        }
    }

    fn data_ready(&self, t: TaskRef, exec: usize) -> f64 {
        let job = &self.jobs[t.job];
        let mut ready = job.arrival;
        for e in &job.parents[t.node] {
            let edge = job.edge_data(e.other, t.node);
            let avail = self.placements[t.job][e.other]
                .iter()
                .map(|&(pe, pf)| pf + self.cluster.transfer_time(edge, pe, exec))
                .fold(f64::INFINITY, f64::min);
            if avail > ready {
                ready = avail;
            }
        }
        ready
    }

    /// The pre-refactor `SimState::apply`, byte for byte.
    fn apply(&mut self, wall: f64, task: TaskRef, alloc: Allocation) -> f64 {
        let exec = alloc.exec();
        let arrival = self.jobs[task.job].arrival;
        if let Allocation::Duplicate { parent, .. } = alloc {
            let p = TaskRef::new(task.job, parent);
            let p_data = self.data_ready(p, exec);
            let start = p_data.max(self.exec_ready[exec]).max(wall).max(arrival);
            let finish =
                start + self.jobs[p.job].tasks[p.node].compute / self.cluster.speed(exec);
            self.placements[p.job][p.node].push((exec, finish));
            self.exec_ready[exec] = finish;
            self.log[exec].push((p, start, finish, true));
        }
        let data = self.data_ready(task, exec);
        let start = data.max(self.exec_ready[exec]).max(wall).max(arrival);
        let finish =
            start + self.jobs[task.job].tasks[task.node].compute / self.cluster.speed(exec);
        self.placements[task.job][task.node].push((exec, finish));
        self.exec_ready[exec] = finish;
        self.log[exec].push((task, start, finish, false));
        finish
    }
}

/// Run `sched` through the real engine, replay its decisions through the
/// reference model, and demand bit-identical bookings.
fn assert_golden(mut sched: Tracing<Box<dyn Scheduler>>, cluster: Cluster, w: Workload) {
    let refmodel_jobs = w.jobs.clone();
    let mut sim = Simulator::new(cluster.clone(), w);
    let report = sim.run(&mut sched).unwrap();
    let name = sched.name();

    let mut reference = RefModel::new(cluster, refmodel_jobs);
    for &(wall, task, alloc) in &sched.log {
        reference.apply(wall, task, alloc);
    }

    for (e, log) in sim.state.exec_log.iter().enumerate() {
        assert_eq!(
            log.len(),
            reference.log[e].len(),
            "{name}: executor {e} booking count"
        );
        for (i, ((t, pl), &(rt, rs, rf, rd))) in
            log.iter().zip(&reference.log[e]).enumerate()
        {
            assert_eq!(*t, rt, "{name}: exec {e} slot {i} task");
            assert_eq!(pl.duplicate, rd, "{name}: exec {e} slot {i} dup flag");
            // Bit-identical, not approximately equal: the timeline math
            // must be the same float operations as the scalar tail.
            assert_eq!(
                pl.start.to_bits(),
                rs.to_bits(),
                "{name}: exec {e} slot {i} start {} vs {rs}",
                pl.start
            );
            assert_eq!(
                pl.finish.to_bits(),
                rf.to_bits(),
                "{name}: exec {e} slot {i} finish {} vs {rf}",
                pl.finish
            );
        }
    }
    // Makespan is derived from the placements, so it matches by
    // construction — keep an explicit check for the report field anyway.
    let ref_makespan = reference
        .log
        .iter()
        .flatten()
        .filter(|&&(_, _, _, dup)| !dup)
        .map(|&(_, _, f, _)| f)
        .fold(0.0f64, f64::max);
    assert_eq!(
        report.makespan.to_bits(),
        ref_makespan.to_bits(),
        "{name}: makespan {} vs {ref_makespan}",
        report.makespan
    );
}

fn zoo(seed: u64) -> Vec<Tracing<Box<dyn Scheduler>>> {
    let scheds: Vec<Box<dyn Scheduler>> = vec![
        Box::new(FifoScheduler::new()),
        Box::new(SjfScheduler::new()),
        Box::new(HrrnScheduler::new()),
        Box::new(HighRankUpScheduler::new()),
        Box::new(HeftScheduler::new()),
        Box::new(CpopScheduler::new()),
        Box::new(DlsScheduler::new()),
        Box::new(TdcaScheduler::new()),
        Box::new(RandomScheduler::new(seed)),
        Box::new(DecimaScheduler::greedy_decima(Box::new(RustPolicy::random(
            seed,
        )))),
        Box::new(LachesisScheduler::greedy(Box::new(RustPolicy::random(
            seed ^ 1,
        )))),
    ];
    scheds.into_iter().map(Tracing::new).collect()
}

#[test]
fn golden_zoo_batch_matches_pre_refactor_semantics() {
    for seed in [11u64, 42, 99] {
        let cfg = ClusterConfig::with_executors(10);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(5), seed).generate();
        for sched in zoo(seed) {
            let cluster = Cluster::heterogeneous(&cfg, seed);
            assert_golden(sched, cluster, w.clone());
        }
    }
}

#[test]
fn golden_zoo_continuous_matches_pre_refactor_semantics() {
    for seed in [7u64, 23] {
        let cfg = ClusterConfig::with_executors(8);
        let w = WorkloadGenerator::new(WorkloadConfig::continuous(6), seed).generate();
        for sched in zoo(seed) {
            let cluster = Cluster::heterogeneous(&cfg, seed);
            assert_golden(sched, cluster, w.clone());
        }
    }
}

/// Zero-fault mode of the fault subsystem: a simulator carrying an empty
/// `FaultPlan` must still match the pre-refactor reference bit-for-bit
/// for the whole zoo — the fault machinery may not perturb the reliable
/// path in any way.
#[test]
fn golden_zoo_with_empty_fault_plan_matches_reference() {
    use lachesis::fault::FaultPlan;
    let seed = 42u64;
    let cfg = ClusterConfig::with_executors(10);
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(5), seed).generate();
    for mut sched in zoo(seed) {
        let cluster = Cluster::heterogeneous(&cfg, seed);
        let refmodel_jobs = w.jobs.clone();
        let mut sim = Simulator::with_faults(cluster.clone(), w.clone(), &FaultPlan::none());
        let report = sim.run(&mut sched).unwrap();
        let name = sched.name();
        let mut reference = RefModel::new(cluster, refmodel_jobs);
        for &(wall, task, alloc) in &sched.log {
            reference.apply(wall, task, alloc);
        }
        for (e, log) in sim.state.exec_log.iter().enumerate() {
            assert_eq!(log.len(), reference.log[e].len(), "{name}: exec {e} count");
            for ((t, pl), &(rt, rs, rf, rd)) in log.iter().zip(&reference.log[e]) {
                assert_eq!(*t, rt, "{name}: task order");
                assert_eq!(pl.duplicate, rd, "{name}: dup flag");
                assert_eq!(pl.start.to_bits(), rs.to_bits(), "{name}: start");
                assert_eq!(pl.finish.to_bits(), rf.to_bits(), "{name}: finish");
            }
        }
        let ref_makespan = reference
            .log
            .iter()
            .flatten()
            .filter(|&&(_, _, _, dup)| !dup)
            .map(|&(_, _, f, _)| f)
            .fold(0.0f64, f64::max);
        assert_eq!(report.makespan.to_bits(), ref_makespan.to_bits(), "{name}");
    }
}

/// Gap-aware booking can only move per-decision finishes earlier than the
/// append booking for the same (task, executor) probe; end-to-end it must
/// still produce valid schedules for the whole zoo.
#[test]
fn gap_aware_zoo_validates() {
    for seed in [5u64, 17] {
        let mut cfg = ClusterConfig::with_executors(8);
        cfg.sched_mode = SchedMode::GapAware;
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(5), seed).generate();
        for mut sched in zoo(seed) {
            let cluster = Cluster::heterogeneous(&cfg, seed);
            let mut sim = Simulator::new(cluster, w.clone());
            let report = sim.run(&mut sched).unwrap();
            assert!(report.makespan.is_finite() && report.makespan > 0.0);
            sim.state.validate().unwrap_or_else(|e| {
                panic!("{} gap-aware validation: {e}", sched.name())
            });
        }
    }
}
