//! Telemetry integration: the golden on/off test pinning every
//! scheduler's schedule bitwise identical with telemetry enabled or
//! disabled, proptest-style histogram merge/thread-invariance checks,
//! Recorder-vs-Histogram percentile agreement, and the `metrics`
//! protocol request served end to end over TCP in both service modes.
//!
//! The telemetry switches are process-global, so the golden test runs
//! its "off" leg first, flips tracing on, and re-runs — any divergence
//! means instrumentation touched an RNG stream, event ordering, or a
//! schedule float, which design rule #1 of [`lachesis::obs`] forbids.

use lachesis::cluster::Cluster;
use lachesis::config::{ClusterConfig, WorkloadConfig};
use lachesis::dag::TaskRef;
use lachesis::obs::metrics::{bucket_index, bucket_upper, Histogram};
use lachesis::obs::trace;
use lachesis::policy::RustPolicy;
use lachesis::sched::{
    CpopScheduler, DecimaScheduler, DlsScheduler, FifoScheduler, HeftScheduler,
    HighRankUpScheduler, HrrnScheduler, LachesisScheduler, RandomScheduler, Scheduler,
    SjfScheduler, TdcaScheduler,
};
use lachesis::service::{AgentServer, Request, Response, ServiceClient, ServiceMode};
use lachesis::sim::Simulator;
use lachesis::util::json::Json;
use lachesis::util::rng::Rng;
use lachesis::util::stats::Recorder;
use lachesis::workload::WorkloadGenerator;

fn zoo(seed: u64) -> Vec<Box<dyn Scheduler>> {
    vec![
        Box::new(FifoScheduler::new()),
        Box::new(SjfScheduler::new()),
        Box::new(HrrnScheduler::new()),
        Box::new(HighRankUpScheduler::new()),
        Box::new(HeftScheduler::new()),
        Box::new(CpopScheduler::new()),
        Box::new(DlsScheduler::new()),
        Box::new(TdcaScheduler::new()),
        Box::new(RandomScheduler::new(seed)),
        Box::new(DecimaScheduler::greedy_decima(Box::new(RustPolicy::random(
            seed,
        )))),
        Box::new(LachesisScheduler::greedy(Box::new(RustPolicy::random(
            seed ^ 1,
        )))),
    ]
}

/// One scheduler's full schedule, reduced to exact bits: per-executor
/// booking logs as (task, start bits, finish bits, duplicate) plus the
/// report makespan bits.
type ScheduleKey = (String, Vec<Vec<(TaskRef, u64, u64, bool)>>, u64);

fn capture_zoo(seed: u64) -> Vec<ScheduleKey> {
    let cfg = ClusterConfig::with_executors(10);
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(5), seed).generate();
    zoo(seed)
        .into_iter()
        .map(|mut sched| {
            let mut sim = Simulator::new(Cluster::heterogeneous(&cfg, seed), w.clone());
            let report = sim
                .run(sched.as_mut())
                .unwrap_or_else(|e| panic!("{} failed: {e}", sched.name()));
            let log = sim
                .state
                .exec_log
                .iter()
                .map(|l| {
                    l.iter()
                        .map(|(t, pl)| (*t, pl.start.to_bits(), pl.finish.to_bits(), pl.duplicate))
                        .collect()
                })
                .collect();
            (sched.name(), log, report.makespan.to_bits())
        })
        .collect()
}

/// The tentpole invariant: enabling metrics + span tracing must leave
/// every schedule in the zoo bitwise unchanged — telemetry only reads
/// clocks and bumps atomics. Also pins that the resulting Chrome trace
/// is valid JSON carrying the decision-loop span taxonomy, so a
/// `--trace-out` file loads in ui.perfetto.dev.
#[test]
fn telemetry_leaves_zoo_schedules_bitwise_unchanged() {
    let seed = 42u64;
    let off = capture_zoo(seed);

    trace::clear();
    trace::start_tracing(); // flips metrics on too
    let on = capture_zoo(seed);
    trace::stop_tracing();

    assert_eq!(off.len(), on.len());
    for (a, b) in off.iter().zip(&on) {
        assert_eq!(a.0, b.0);
        assert_eq!(a.2, b.2, "{}: makespan bits changed with telemetry on", a.0);
        assert_eq!(a.1, b.1, "{}: schedule changed with telemetry on", a.0);
    }

    let path = std::env::temp_dir().join(format!(
        "lachesis_obs_trace_{}.json",
        std::process::id()
    ));
    trace::dump_chrome_trace(path.to_str().unwrap()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let parsed = Json::parse(&text).expect("chrome trace must be valid JSON");
    let events = parsed
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("traceEvents array");
    let has = |name: &str| {
        events
            .iter()
            .any(|e| e.get("name").and_then(|n| n.as_str()) == Some(name))
    };
    // The sim decision loop, the two-phase scheduler, and the policy
    // forward all ran under tracing — their spans must be in the dump.
    for name in ["decision", "apply", "select", "allocate", "encode", "forward"] {
        assert!(has(name), "trace is missing span {name:?}");
    }
    std::fs::remove_file(&path).ok();
}

/// Log-uniform latencies spanning the histogram's full range — the
/// distribution that stresses bucket boundaries hardest.
fn random_latencies(seed: u64, n: usize) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| 10f64.powf(-3.0 + 7.0 * rng.next_f64()))
        .collect()
}

/// Proptest-style: across random sample sets, recording a stream split
/// round-robin over k histograms and merging is *exactly* recording the
/// whole stream into one histogram — bucket counts are integers, so no
/// tolerance. k includes 1 (merge of a single part is the identity).
#[test]
fn histogram_merge_equals_single_histogram() {
    for seed in [1u64, 7, 23, 99] {
        let samples = random_latencies(seed, 503); // odd: uneven chunks
        let single = Histogram::new();
        for &v in &samples {
            single.record(v);
        }
        for k in [1usize, 2, 4] {
            let parts: Vec<Histogram> = (0..k).map(|_| Histogram::new()).collect();
            for (i, &v) in samples.iter().enumerate() {
                parts[i % k].record(v);
            }
            let merged = Histogram::new();
            for p in &parts {
                merged.merge_from(p);
            }
            assert_eq!(merged.count(), single.count(), "seed {seed} k {k}");
            assert_eq!(
                merged.bucket_counts(),
                single.bucket_counts(),
                "seed {seed} k {k}: merge must equal single-histogram recording"
            );
        }
    }
}

/// Bucket counts are invariant to the number of recording threads: k
/// threads hammering one shared histogram produce exactly the
/// single-thread counts, so soak latencies don't depend on master count.
#[test]
fn histogram_bucket_counts_are_thread_count_invariant() {
    let samples = random_latencies(42, 800);
    let single = Histogram::new();
    for &v in &samples {
        single.record(v);
    }
    for k in [1usize, 2, 4] {
        let shared = Histogram::new();
        std::thread::scope(|s| {
            for chunk in samples.chunks((samples.len() + k - 1) / k) {
                let shared = &shared;
                s.spawn(move || {
                    for &v in chunk {
                        shared.record(v);
                    }
                });
            }
        });
        assert_eq!(shared.count(), single.count(), "k {k}");
        assert_eq!(
            shared.bucket_counts(),
            single.bucket_counts(),
            "k {k}: thread count must not change bucket counts"
        );
    }
}

/// The soak's percentile contract: the histogram estimate is the upper
/// edge of the bucket holding the nearest-rank sample — deterministic,
/// and within one bucket width (≤ 13%) of the exact `Recorder` value.
#[test]
fn histogram_percentiles_agree_with_recorder() {
    for seed in [3u64, 11] {
        let samples = random_latencies(seed, 1000);
        let hist = Histogram::new();
        let mut rec = Recorder::new();
        for &v in &samples {
            hist.record(v);
            rec.push(v);
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        for p in [50.0, 95.0, 99.0] {
            // Exact pin of the convention documented on `percentile`.
            let rank = ((p / 100.0) * ((n - 1) as f64)).ceil() as usize;
            let expect = bucket_upper(bucket_index(sorted[rank]));
            let got = hist.percentile(p);
            assert_eq!(
                got.to_bits(),
                expect.to_bits(),
                "seed {seed} p{p}: histogram percentile convention drifted"
            );
            // Agreement with the exact recorder: the recorder's
            // interpolated value lies at or below the nearest-rank
            // sample, which lies inside the reported bucket.
            let exact = rec.percentile(p);
            assert!(
                got >= exact && got <= exact * 1.14,
                "seed {seed} p{p}: histogram {got} vs exact {exact}"
            );
        }
    }
}

/// `{"type":"metrics"}` over real TCP in both engines: answered without
/// touching the core lock, with a parseable Prometheus payload carrying
/// the request counters and a JSON series array.
#[test]
fn metrics_request_served_in_both_modes() {
    for mode in [ServiceMode::Serial, ServiceMode::Batched] {
        let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(6), 3);
        let agent = AgentServer::with_mode(
            cluster,
            Box::new(HighRankUpScheduler::new()),
            mode,
        );
        let (tx, rx) = std::sync::mpsc::channel();
        let handle = std::thread::spawn(move || {
            agent
                .serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
                .unwrap();
        });
        let addr = rx.recv().unwrap().to_string();
        let mut client = ServiceClient::connect(&addr).unwrap();

        // Put some traffic on the wire so the counters are non-zero.
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(2), 3).generate();
        for job in &w.jobs {
            let computes: Vec<f64> = job.tasks.iter().map(|t| t.compute).collect();
            let edges: Vec<(usize, usize, f64)> = (0..job.n_tasks())
                .flat_map(|u| {
                    job.children[u]
                        .iter()
                        .map(move |e| (u, e.other, e.data))
                        .collect::<Vec<_>>()
                })
                .collect();
            client
                .call(&Request::SubmitJob {
                    name: job.name.clone(),
                    arrival: job.arrival,
                    computes,
                    edges,
                })
                .unwrap();
        }
        client.call(&Request::Schedule { time: 0.0 }).unwrap();

        match client.call(&Request::Metrics).unwrap() {
            Response::Metrics { prometheus, series } => {
                assert!(
                    prometheus.contains("lachesis_requests_total"),
                    "{mode:?}: scrape missing the request counter family"
                );
                assert!(
                    prometheus.contains("# TYPE"),
                    "{mode:?}: scrape missing TYPE comments"
                );
                let arr = series.as_arr().expect("series must be a JSON array");
                assert!(!arr.is_empty(), "{mode:?}: series must be non-empty");
            }
            other => panic!("{mode:?}: unexpected metrics response {other:?}"),
        }
        client.call(&Request::Shutdown).unwrap();
        handle.join().unwrap();
    }
}
