//! Fault-injection integration suite: determinism of faulty runs, the
//! zero-fault == no-plan equivalence, crash survival across the zoo, and
//! the robustness sweep's thread-count invariance.

use lachesis::cluster::Cluster;
use lachesis::config::{ClusterConfig, FaultConfig, WorkloadConfig};
use lachesis::exp::{self, PolicySource};
use lachesis::fault::FaultPlan;
use lachesis::policy::RustPolicy;
use lachesis::sched::{
    FifoScheduler, HeftScheduler, HighRankUpScheduler, LachesisScheduler, Scheduler,
    TdcaScheduler,
};
use lachesis::sim::{Placement, Simulator};
use lachesis::workload::WorkloadGenerator;

/// The fault-relevant scheduler sample: heuristic with and without
/// duplication, whole-DAG, and learned.
fn zoo(seed: u64) -> Vec<Box<dyn Scheduler>> {
    vec![
        Box::new(FifoScheduler::new()),
        Box::new(HighRankUpScheduler::new()),
        Box::new(HeftScheduler::new()),
        Box::new(TdcaScheduler::new()),
        Box::new(LachesisScheduler::greedy(Box::new(RustPolicy::random(seed)))),
    ]
}

fn exec_log_bits(sim: &Simulator) -> Vec<Vec<(usize, usize, u64, u64, bool)>> {
    sim.state
        .exec_log
        .iter()
        .map(|log| {
            log.iter()
                .map(|(t, pl): &(lachesis::dag::TaskRef, Placement)| {
                    (
                        t.job,
                        t.node,
                        pl.start.to_bits(),
                        pl.finish.to_bits(),
                        pl.duplicate,
                    )
                })
                .collect()
        })
        .collect()
}

/// Same `(FaultConfig, seed)` → bit-identical schedules, run after run.
#[test]
fn faulty_runs_are_bit_deterministic() {
    let fcfg = FaultConfig::with_rate(2e-3);
    for seed in [3u64, 19] {
        let ccfg = ClusterConfig::with_executors(10);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(4), seed).generate();
        for mk in 0..zoo(seed).len() {
            let run = || {
                let cluster = Cluster::heterogeneous(&ccfg, seed);
                let plan = FaultPlan::generate(&fcfg, cluster.len(), seed);
                let mut sched = zoo(seed).remove(mk);
                let mut sim = Simulator::with_faults(cluster, w.clone(), &plan);
                let report = sim.run(sched.as_mut()).unwrap_or_else(|e| {
                    panic!("seed {seed} {}: {e}", sched.name())
                });
                (exec_log_bits(&sim), report.makespan.to_bits())
            };
            let a = run();
            let b = run();
            assert_eq!(a, b, "seed {seed} scheduler #{mk} diverged");
        }
    }
}

/// Attaching `FaultPlan::none()` (or a `FaultConfig::none()`-generated
/// plan) must be bit-identical to attaching no plan at all — the
/// zero-fault acceptance gate for the whole subsystem.
#[test]
fn zero_fault_plan_is_bit_identical_to_no_plan() {
    for seed in [11u64, 42] {
        let ccfg = ClusterConfig::with_executors(8);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(4), seed).generate();
        for mk in 0..zoo(seed).len() {
            let plain = {
                let mut sched = zoo(seed).remove(mk);
                let mut sim =
                    Simulator::new(Cluster::heterogeneous(&ccfg, seed), w.clone());
                let r = sim.run(sched.as_mut()).unwrap();
                (exec_log_bits(&sim), r.makespan.to_bits(), r.speedup.to_bits())
            };
            for plan in [
                FaultPlan::none(),
                FaultPlan::generate(&FaultConfig::none(), 8, seed),
            ] {
                let mut sched = zoo(seed).remove(mk);
                let mut sim = Simulator::with_faults(
                    Cluster::heterogeneous(&ccfg, seed),
                    w.clone(),
                    &plan,
                );
                let r = sim.run(sched.as_mut()).unwrap();
                let got =
                    (exec_log_bits(&sim), r.makespan.to_bits(), r.speedup.to_bits());
                assert_eq!(plain, got, "seed {seed} scheduler #{mk}: zero-fault drift");
            }
        }
    }
}

/// Injected crashes are survived: no unassigned tasks, every job
/// completes, and the composed state (blackouts included) validates.
#[test]
fn crashes_are_survived_across_the_zoo() {
    for &rate in &[1e-3, 5e-3] {
        let fcfg = FaultConfig::with_rate(rate);
        for seed in [2u64, 7, 13] {
            let ccfg = ClusterConfig::with_executors(8);
            let w = WorkloadGenerator::new(WorkloadConfig::small_batch(4), seed).generate();
            for mk in 0..zoo(seed).len() {
                let cluster = Cluster::heterogeneous(&ccfg, seed);
                let plan = FaultPlan::generate(&fcfg, cluster.len(), seed);
                let mut sched = zoo(seed).remove(mk);
                let mut sim = Simulator::with_faults(cluster, w.clone(), &plan);
                let report = sim.run(sched.as_mut()).unwrap_or_else(|e| {
                    panic!("rate {rate} seed {seed} {}: {e}", sched.name())
                });
                assert!(sim.state.all_assigned());
                assert!(report.makespan.is_finite() && report.makespan > 0.0);
                for ji in 0..sim.state.jobs.len() {
                    assert!(
                        sim.state.job_completion(ji).is_finite(),
                        "rate {rate} seed {seed} {}: job {ji} incomplete",
                        sched.name()
                    );
                }
                sim.state.validate().unwrap_or_else(|e| {
                    panic!("rate {rate} seed {seed} {}: {e}", sched.name())
                });
            }
        }
    }
}

/// At least one of the survival scenarios actually exercises faults (the
/// rates above are high enough that silence would mean a plumbing bug),
/// and both recovery paths — duplication promotion and requeue — occur
/// somewhere in the sample.
#[test]
fn fault_machinery_actually_fires() {
    let fcfg = FaultConfig::with_rate(5e-3);
    let mut crashes = 0usize;
    let mut requeued = 0usize;
    let mut survived = 0usize;
    for seed in 0..8u64 {
        let ccfg = ClusterConfig::with_executors(8);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(5), seed).generate();
        let cluster = Cluster::heterogeneous(&ccfg, seed);
        let plan = FaultPlan::generate(&fcfg, cluster.len(), seed);
        let mut sched = FifoScheduler::new();
        let mut sim = Simulator::with_faults(cluster, w, &plan);
        sim.run(&mut sched).unwrap();
        crashes += sim.state.faults.n_crashes;
        requeued += sim.state.faults.n_requeued;
        survived += sim.state.faults.n_dup_survived;
    }
    assert!(crashes > 0, "no crash ever processed");
    assert!(
        requeued + survived > 0,
        "no task was ever disrupted across 8 seeds at rate 5e-3"
    );
}

/// The robustness sweep is thread-count invariant (schedules and all
/// derived columns are deterministic; the sweep records no wall-clock
/// column).
#[test]
fn fault_sweep_is_thread_invariant() {
    let src = PolicySource {
        backend: "rust".into(),
        ..Default::default()
    };
    let rates = [0.0, 2e-3];
    let seq = exp::fault_sweep(&src, &rates, 2, 2, 1).unwrap();
    let par = exp::fault_sweep(&src, &rates, 2, 2, 4).unwrap();
    assert_eq!(seq, par, "fault sweep must not depend on thread count");
}

/// The engine's unassigned-task error names the stranded jobs (not just
/// a count).
#[test]
fn unassigned_error_names_stranded_jobs() {
    struct Refuser;
    impl Scheduler for Refuser {
        fn name(&self) -> String {
            "refuser".into()
        }
        fn step(
            &mut self,
            _state: &lachesis::sim::SimState,
        ) -> anyhow::Result<Option<(lachesis::dag::TaskRef, lachesis::sim::Allocation)>>
        {
            Ok(None)
        }
    }
    let cluster = Cluster::homogeneous(2, 1.0, 10.0);
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(2), 1).generate();
    let names: Vec<String> = w.jobs.iter().map(|j| j.name.clone()).collect();
    let mut sim = Simulator::new(cluster, w);
    let err = sim.run(&mut Refuser).unwrap_err().to_string();
    assert!(err.contains("unassigned"), "{err}");
    for (ji, name) in names.iter().enumerate() {
        assert!(
            err.contains(&format!("job {ji} '{name}'")),
            "error must name job {ji} '{name}': {err}"
        );
    }
}
