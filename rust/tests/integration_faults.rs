//! Fault-injection integration suite: determinism of faulty runs, the
//! zero-fault == no-plan equivalence, crash survival across the zoo, and
//! the robustness sweep's thread-count invariance.

use lachesis::cluster::Cluster;
use lachesis::config::{ClusterConfig, FaultConfig, WorkloadConfig};
use lachesis::exp::{self, PolicySource};
use lachesis::fault::FaultPlan;
use lachesis::policy::RustPolicy;
use lachesis::sched::{
    FifoScheduler, HeftScheduler, HighRankUpScheduler, LachesisScheduler, Scheduler,
    TdcaScheduler,
};
use lachesis::sim::{Placement, Simulator};
use lachesis::workload::WorkloadGenerator;

/// The fault-relevant scheduler sample: heuristic with and without
/// duplication, whole-DAG, and learned.
fn zoo(seed: u64) -> Vec<Box<dyn Scheduler>> {
    vec![
        Box::new(FifoScheduler::new()),
        Box::new(HighRankUpScheduler::new()),
        Box::new(HeftScheduler::new()),
        Box::new(TdcaScheduler::new()),
        Box::new(LachesisScheduler::greedy(Box::new(RustPolicy::random(seed)))),
    ]
}

fn exec_log_bits(sim: &Simulator) -> Vec<Vec<(usize, usize, u64, u64, bool)>> {
    sim.state
        .exec_log
        .iter()
        .map(|log| {
            log.iter()
                .map(|(t, pl): &(lachesis::dag::TaskRef, Placement)| {
                    (
                        t.job,
                        t.node,
                        pl.start.to_bits(),
                        pl.finish.to_bits(),
                        pl.duplicate,
                    )
                })
                .collect()
        })
        .collect()
}

/// Same `(FaultConfig, seed)` → bit-identical schedules, run after run.
#[test]
fn faulty_runs_are_bit_deterministic() {
    let fcfg = FaultConfig::with_rate(2e-3);
    for seed in [3u64, 19] {
        let ccfg = ClusterConfig::with_executors(10);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(4), seed).generate();
        for mk in 0..zoo(seed).len() {
            let run = || {
                let cluster = Cluster::heterogeneous(&ccfg, seed);
                let plan = FaultPlan::generate(&fcfg, cluster.len(), seed);
                let mut sched = zoo(seed).remove(mk);
                let mut sim = Simulator::with_faults(cluster, w.clone(), &plan);
                let report = sim.run(sched.as_mut()).unwrap_or_else(|e| {
                    panic!("seed {seed} {}: {e}", sched.name())
                });
                (exec_log_bits(&sim), report.makespan.to_bits())
            };
            let a = run();
            let b = run();
            assert_eq!(a, b, "seed {seed} scheduler #{mk} diverged");
        }
    }
}

/// Attaching `FaultPlan::none()` (or a `FaultConfig::none()`-generated
/// plan) must be bit-identical to attaching no plan at all — the
/// zero-fault acceptance gate for the whole subsystem.
#[test]
fn zero_fault_plan_is_bit_identical_to_no_plan() {
    for seed in [11u64, 42] {
        let ccfg = ClusterConfig::with_executors(8);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(4), seed).generate();
        for mk in 0..zoo(seed).len() {
            let plain = {
                let mut sched = zoo(seed).remove(mk);
                let mut sim =
                    Simulator::new(Cluster::heterogeneous(&ccfg, seed), w.clone());
                let r = sim.run(sched.as_mut()).unwrap();
                (exec_log_bits(&sim), r.makespan.to_bits(), r.speedup.to_bits())
            };
            for plan in [
                FaultPlan::none(),
                FaultPlan::generate(&FaultConfig::none(), 8, seed),
            ] {
                let mut sched = zoo(seed).remove(mk);
                let mut sim = Simulator::with_faults(
                    Cluster::heterogeneous(&ccfg, seed),
                    w.clone(),
                    &plan,
                );
                let r = sim.run(sched.as_mut()).unwrap();
                let got =
                    (exec_log_bits(&sim), r.makespan.to_bits(), r.speedup.to_bits());
                assert_eq!(plain, got, "seed {seed} scheduler #{mk}: zero-fault drift");
            }
        }
    }
}

/// Injected crashes are survived: no unassigned tasks, every job
/// completes, and the composed state (blackouts included) validates.
#[test]
fn crashes_are_survived_across_the_zoo() {
    for &rate in &[1e-3, 5e-3] {
        let fcfg = FaultConfig::with_rate(rate);
        for seed in [2u64, 7, 13] {
            let ccfg = ClusterConfig::with_executors(8);
            let w = WorkloadGenerator::new(WorkloadConfig::small_batch(4), seed).generate();
            for mk in 0..zoo(seed).len() {
                let cluster = Cluster::heterogeneous(&ccfg, seed);
                let plan = FaultPlan::generate(&fcfg, cluster.len(), seed);
                let mut sched = zoo(seed).remove(mk);
                let mut sim = Simulator::with_faults(cluster, w.clone(), &plan);
                let report = sim.run(sched.as_mut()).unwrap_or_else(|e| {
                    panic!("rate {rate} seed {seed} {}: {e}", sched.name())
                });
                assert!(sim.state.all_assigned());
                assert!(report.makespan.is_finite() && report.makespan > 0.0);
                for ji in 0..sim.state.jobs.len() {
                    assert!(
                        sim.state.job_completion(ji).is_finite(),
                        "rate {rate} seed {seed} {}: job {ji} incomplete",
                        sched.name()
                    );
                }
                sim.state.validate().unwrap_or_else(|e| {
                    panic!("rate {rate} seed {seed} {}: {e}", sched.name())
                });
            }
        }
    }
}

/// At least one of the survival scenarios actually exercises faults (the
/// rates above are high enough that silence would mean a plumbing bug),
/// and both recovery paths — duplication promotion and requeue — occur
/// somewhere in the sample.
#[test]
fn fault_machinery_actually_fires() {
    let fcfg = FaultConfig::with_rate(5e-3);
    let mut crashes = 0usize;
    let mut requeued = 0usize;
    let mut survived = 0usize;
    for seed in 0..8u64 {
        let ccfg = ClusterConfig::with_executors(8);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(5), seed).generate();
        let cluster = Cluster::heterogeneous(&ccfg, seed);
        let plan = FaultPlan::generate(&fcfg, cluster.len(), seed);
        let mut sched = FifoScheduler::new();
        let mut sim = Simulator::with_faults(cluster, w, &plan);
        sim.run(&mut sched).unwrap();
        crashes += sim.state.faults.n_crashes;
        requeued += sim.state.faults.n_requeued;
        survived += sim.state.faults.n_dup_survived;
    }
    assert!(crashes > 0, "no crash ever processed");
    assert!(
        requeued + survived > 0,
        "no task was ever disrupted across 8 seeds at rate 5e-3"
    );
}

/// The robustness sweep is thread-count invariant (schedules and all
/// derived columns are deterministic; the sweep records no wall-clock
/// column).
#[test]
fn fault_sweep_is_thread_invariant() {
    let src = PolicySource {
        backend: "rust".into(),
        ..Default::default()
    };
    let rates = [0.0, 2e-3];
    let seq = exp::fault_sweep(&src, &rates, 2, 2, 1).unwrap();
    let par = exp::fault_sweep(&src, &rates, 2, 2, 4).unwrap();
    assert_eq!(seq, par, "fault sweep must not depend on thread count");
}

/// A rack incident is one correlated crash: the generated plan downs
/// every member of the rack at the same instant with one shared
/// recovery, and the engine survives the plan with a `validate()`-clean
/// final state across the zoo.
#[test]
fn rack_failures_down_whole_racks_and_are_survived() {
    use lachesis::net::NetConfig;
    let mut ccfg = ClusterConfig::with_executors(8);
    ccfg.net = NetConfig::tree(2, 4);
    for seed in [3u64, 11] {
        let cluster = Cluster::heterogeneous(&ccfg, seed);
        let mut fcfg = FaultConfig::none();
        fcfg.rack_rate = 2e-3;
        let plan = FaultPlan::generate_with_topology(&fcfg, &cluster.net, seed);
        assert!(!plan.events.is_empty(), "seed {seed}: rate high enough to fire");
        // Correlation: group by crash instant — each group must be
        // exactly one whole rack.
        let mut groups: std::collections::BTreeMap<u64, Vec<usize>> = Default::default();
        for ev in &plan.events {
            groups.entry(ev.time.to_bits()).or_default().push(ev.exec);
        }
        for (t, execs) in &groups {
            let rack = cluster.rack_of(execs[0]);
            assert_eq!(
                *execs,
                cluster.net.rack_members(rack),
                "seed {seed} t={t:016x}: incident must cover rack {rack} exactly"
            );
        }
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(4), seed).generate();
        for mk in 0..zoo(seed).len() {
            let mut sched = zoo(seed).remove(mk);
            let mut sim =
                Simulator::with_faults(Cluster::heterogeneous(&ccfg, seed), w.clone(), &plan);
            let report = sim
                .run(sched.as_mut())
                .unwrap_or_else(|e| panic!("seed {seed} {}: {e}", sched.name()));
            assert!(sim.state.all_assigned());
            assert!(report.makespan.is_finite() && report.makespan > 0.0);
            sim.state
                .validate()
                .unwrap_or_else(|e| panic!("seed {seed} {}: {e}", sched.name()));
        }
    }
}

/// Duplication-aware recovery across racks: when a whole rack dies, a
/// task whose duplicate copy lives in another rack is promoted in place
/// instead of requeued — and the state validates after every single
/// member crash of the rack event.
#[test]
fn rack_crash_promotes_surviving_cross_rack_copy() {
    use lachesis::dag::{Job, TaskRef};
    use lachesis::net::NetConfig;
    use lachesis::sim::{Allocation, SimState};
    use lachesis::workload::Workload;
    let cluster = Cluster::homogeneous(4, 1.0, 10.0).with_net(&NetConfig::tree(2, 2));
    let job = Job::new(0, "chain", 0.0, vec![4.0, 2.0], &[(0, 1, 6.0)]);
    let mut st = SimState::new(cluster, Workload::new(vec![job]));
    st.mark_arrived(0);
    // Parent primary in rack 0; DEFT duplicates it across the uplink
    // onto rack 1 alongside the child.
    st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 });
    st.apply(TaskRef::new(0, 1), Allocation::Duplicate { exec: 2, parent: 0 });
    assert_eq!(st.n_duplicates, 1);
    // Rack 0 dies mid-flight: every member crashes at the same instant.
    let members: Vec<usize> = (0..st.cluster.len())
        .filter(|&e| st.cluster.rack_of(e) == 0)
        .collect();
    assert_eq!(members, vec![0, 1]);
    let mut survived = 0usize;
    for &e in &members {
        let out = st.apply_crash(e, 1.0, Some(20.0));
        survived += out.survived;
        st.validate()
            .unwrap_or_else(|err| panic!("after rack-0 member {e} crash: {err}"));
        assert!(!st.exec_available(e));
    }
    // Rack 1 is untouched; the parent survived via its rack-1 copy.
    assert!(st.exec_available(2) && st.exec_available(3));
    assert_eq!(survived, 1, "cross-rack duplicate must be promoted");
    assert_eq!(st.faults.n_dup_survived, 1);
    assert!(st.all_assigned(), "nothing requeued: promotion saved the task");
    assert_eq!(st.placements[0][0].len(), 1);
    let promoted = st.placements[0][0][0];
    assert!(!promoted.duplicate, "surviving copy is primary now");
    assert_eq!(st.cluster.rack_of(promoted.exec), 1, "survivor is cross-rack");
    assert_eq!(st.n_duplicates, 0);
}

/// `rack_rate: 0.0` must leave fault plans bitwise unchanged — the
/// topology-aware generator is invisible unless opted into (the same
/// gate the zero-fault plan passes for the base subsystem).
#[test]
fn zero_rack_rate_plans_are_bitwise_unchanged() {
    use lachesis::net::NetConfig;
    let mut ccfg = ClusterConfig::with_executors(9);
    ccfg.net = NetConfig::tree(3, 3);
    for seed in [2u64, 29] {
        let cluster = Cluster::heterogeneous(&ccfg, seed);
        let fcfg = FaultConfig::with_rate(2e-3);
        assert_eq!(fcfg.rack_rate, 0.0);
        let base = FaultPlan::generate(&fcfg, cluster.len(), seed);
        let topo = FaultPlan::generate_with_topology(&fcfg, &cluster.net, seed);
        assert_eq!(
            base.events.len(),
            topo.events.len(),
            "seed {seed}: event count drifted"
        );
        for (a, b) in base.events.iter().zip(&topo.events) {
            assert_eq!(a.exec, b.exec, "seed {seed}");
            assert_eq!(a.time.to_bits(), b.time.to_bits(), "seed {seed}");
        }
    }
}

/// The engine's unassigned-task error names the stranded jobs (not just
/// a count).
#[test]
fn unassigned_error_names_stranded_jobs() {
    struct Refuser;
    impl Scheduler for Refuser {
        fn name(&self) -> String {
            "refuser".into()
        }
        fn step(
            &mut self,
            _state: &lachesis::sim::SimState,
        ) -> anyhow::Result<Option<(lachesis::dag::TaskRef, lachesis::sim::Allocation)>>
        {
            Ok(None)
        }
    }
    let cluster = Cluster::homogeneous(2, 1.0, 10.0);
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(2), 1).generate();
    let names: Vec<String> = w.jobs.iter().map(|j| j.name.clone()).collect();
    let mut sim = Simulator::new(cluster, w);
    let err = sim.run(&mut Refuser).unwrap_err().to_string();
    assert!(err.contains("unassigned"), "{err}");
    for (ji, name) in names.iter().enumerate() {
        assert!(
            err.contains(&format!("job {ji} '{name}'")),
            "error must name job {ji} '{name}': {err}"
        );
    }
}
