//! RL training integration through the AOT train_step artifact: the full
//! loop (rollout → returns → Adam update inside XLA) must run, change
//! parameters, and reduce the imitation loss. Requires `make artifacts`
//! and the `pjrt` cargo feature; without the feature this whole test
//! target compiles to nothing.
#![cfg(feature = "pjrt")]

use lachesis::config::TrainConfig;
use lachesis::policy::features::FeatureMode;
use lachesis::policy::{net, params};
use lachesis::rl::trainer::{PjrtTrainBackend, TrainBackend, Trainer};

const ART: &str = "artifacts";

fn artifacts_available() -> bool {
    std::path::Path::new(&format!("{ART}/meta.json")).exists()
}

fn init_params() -> Vec<f32> {
    params::load_expected(&format!("{ART}/params_init.bin"), net::param_len()).unwrap()
}

fn quick_cfg() -> TrainConfig {
    TrainConfig {
        episodes: 3,
        agents: 2,
        jobs_per_episode: 2,
        executors: 6,
        imitation_epochs: 0,
        ..Default::default()
    }
}

#[test]
fn train_step_artifact_updates_parameters() {
    if !artifacts_available() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let init = init_params();
    let backend = PjrtTrainBackend::new(ART, init.clone()).unwrap();
    let batch = backend.batch_size();
    let mut trainer = Trainer::new(quick_cfg(), backend, FeatureMode::Full);
    let stats = trainer.train(batch).unwrap();
    assert_eq!(stats.len(), 3);
    for s in &stats {
        assert!(s.loss.is_finite());
        assert!(s.entropy.is_finite());
        assert!(s.makespan > 0.0);
    }
    assert_ne!(
        trainer.backend.params(),
        &init[..],
        "parameters must move after updates"
    );
}

#[test]
fn imitation_warmstart_reduces_cross_entropy() {
    if !artifacts_available() {
        eprintln!("skipping: no artifacts");
        return;
    }
    // Collect a fixed expert batch, measure CE before/after several
    // imitation updates on that batch: it must go down.
    use lachesis::cluster::Cluster;
    use lachesis::config::{ClusterConfig, WorkloadConfig};
    use lachesis::rl::trainer::RecordingExpert;
    use lachesis::sched::HeftScheduler;
    use lachesis::sim::Simulator;
    use lachesis::workload::WorkloadGenerator;

    let mut expert = RecordingExpert::new(HeftScheduler::new(), FeatureMode::Full);
    let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(6), 11);
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(2), 11).generate();
    let mut sim = Simulator::new(cluster, w);
    sim.run(&mut expert).unwrap();
    assert!(!expert.rows.is_empty());

    let mut backend = PjrtTrainBackend::new(ART, init_params()).unwrap();
    let b = backend.batch_size();
    let rows: Vec<_> = expert.rows.drain(..).collect();
    let chunk = &rows[..rows.len().min(b)];
    let mut losses = Vec::new();
    for _ in 0..8 {
        let l = backend.update(chunk, 1e-3, 0.0, 0.0).unwrap();
        losses.push(l[0]);
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "imitation CE should fall: {losses:?}"
    );
}

#[test]
fn training_then_inference_roundtrip_via_files() {
    if !artifacts_available() {
        eprintln!("skipping: no artifacts");
        return;
    }
    // Train a couple of episodes, checkpoint, reload into a greedy
    // Lachesis scheduler, and run a schedule.
    let backend = PjrtTrainBackend::new(ART, init_params()).unwrap();
    let batch = backend.batch_size();
    let mut cfg = quick_cfg();
    cfg.episodes = 2;
    let mut trainer = Trainer::new(cfg, backend, FeatureMode::Full);
    trainer.train(batch).unwrap();
    let dir = "/tmp/lachesis_train_roundtrip";
    std::fs::create_dir_all(dir).unwrap();
    let path = format!("{dir}/p.bin");
    params::save_f32(&path, trainer.backend.params()).unwrap();

    use lachesis::cluster::Cluster;
    use lachesis::config::{ClusterConfig, WorkloadConfig};
    use lachesis::runtime::PjrtPolicy;
    use lachesis::sched::LachesisScheduler;
    use lachesis::sim::Simulator;
    use lachesis::workload::WorkloadGenerator;
    let policy = PjrtPolicy::new(ART, Some(&path)).unwrap();
    let mut sched = LachesisScheduler::greedy(Box::new(policy));
    let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(8), 13);
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(3), 13).generate();
    let mut sim = Simulator::new(cluster, w);
    let report = sim.run(&mut sched).unwrap();
    assert!(report.makespan > 0.0);
    sim.state.validate().unwrap();
    std::fs::remove_dir_all(dir).ok();
}
