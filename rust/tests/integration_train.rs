//! RL training integration. The CPU tests run on every build — the full
//! loop (parallel rollouts → returns → analytic backprop + Adam) must
//! run, change parameters, reduce the imitation loss, and produce the
//! same trajectory for every worker-thread count. The `pjrt` module
//! additionally exercises the AOT train_step artifact; it needs
//! `make artifacts` and the `pjrt` cargo feature.

use lachesis::cluster::Cluster;
use lachesis::config::{ClusterConfig, TrainConfig, WorkloadConfig};
use lachesis::policy::features::FeatureMode;
use lachesis::policy::{params, RustPolicy};
use lachesis::rl::cpu_backend::{CpuTrainBackend, CPU_TRAIN_BATCH};
use lachesis::rl::trainer::{RecordingExpert, TrainBackend, Trainer};
use lachesis::sched::{HeftScheduler, LachesisScheduler};
use lachesis::sim::Simulator;
use lachesis::workload::WorkloadGenerator;

fn quick_cfg() -> TrainConfig {
    TrainConfig {
        episodes: 3,
        agents: 2,
        jobs_per_episode: 2,
        executors: 6,
        imitation_epochs: 0,
        ..Default::default()
    }
}

#[test]
fn cpu_train_updates_parameters() {
    let init = RustPolicy::random_params(41);
    let backend = CpuTrainBackend::new(init.clone());
    let mut trainer = Trainer::new(quick_cfg(), backend, FeatureMode::Full);
    let stats = trainer.train(CPU_TRAIN_BATCH).unwrap();
    assert_eq!(stats.len(), 3);
    for s in &stats {
        assert!(s.loss.is_finite());
        assert!(s.entropy.is_finite());
        assert!(s.makespan > 0.0);
    }
    assert_ne!(
        trainer.backend.params(),
        &init[..],
        "parameters must move after updates"
    );
}

#[test]
fn cpu_imitation_warmstart_reduces_cross_entropy() {
    // Collect a fixed expert batch, measure CE before/after several
    // imitation updates on that batch: it must go down.
    let mut expert = RecordingExpert::new(HeftScheduler::new(), FeatureMode::Full);
    let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(6), 11);
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(2), 11).generate();
    let mut sim = Simulator::new(cluster, w);
    sim.run(&mut expert).unwrap();
    assert!(!expert.rows.is_empty());

    let mut backend = CpuTrainBackend::new(RustPolicy::random_params(42));
    let rows: Vec<_> = expert.rows.drain(..).collect();
    let chunk = &rows[..rows.len().min(CPU_TRAIN_BATCH)];
    let mut losses = Vec::new();
    for _ in 0..8 {
        let l = backend.update(chunk, 1e-3, 0.0, 0.0).unwrap();
        losses.push(l[0]);
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "imitation CE should fall: {losses:?}"
    );
}

#[test]
fn cpu_training_then_inference_roundtrip_via_files() {
    // Train a couple of episodes, checkpoint, reload into a greedy
    // Lachesis scheduler, and run a schedule.
    let backend = CpuTrainBackend::new(RustPolicy::random_params(43));
    let mut cfg = quick_cfg();
    cfg.episodes = 2;
    let mut trainer = Trainer::new(cfg, backend, FeatureMode::Full);
    trainer.train(CPU_TRAIN_BATCH).unwrap();
    let dir = "/tmp/lachesis_cpu_train_roundtrip";
    std::fs::create_dir_all(dir).unwrap();
    let path = format!("{dir}/p.bin");
    params::save_f32(&path, trainer.backend.params()).unwrap();

    let loaded = params::load_expected(&path, lachesis::policy::net::param_len()).unwrap();
    let mut sched = LachesisScheduler::greedy(Box::new(RustPolicy::new(loaded)));
    let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(8), 13);
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(3), 13).generate();
    let mut sim = Simulator::new(cluster, w);
    let report = sim.run(&mut sched).unwrap();
    assert!(report.makespan > 0.0);
    sim.state.validate().unwrap();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn threaded_train_matches_sequential() {
    // The training trajectory must be bit-identical for every worker
    // thread count: agent sample streams are derived from the episode
    // master seed, not from which thread runs which rollout.
    let run = |threads: usize| {
        let mut cfg = quick_cfg();
        cfg.threads = threads;
        let backend = CpuTrainBackend::new(RustPolicy::random_params(44));
        let mut trainer = Trainer::new(cfg, backend, FeatureMode::Full);
        let stats = trainer.train(CPU_TRAIN_BATCH).unwrap();
        (stats, trainer.backend.params().to_vec())
    };
    let (seq_stats, seq_params) = run(1);
    let (par_stats, par_params) = run(4);
    assert_eq!(seq_stats.len(), par_stats.len());
    for (a, b) in seq_stats.iter().zip(&par_stats) {
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "ep {}", a.episode);
        assert_eq!(a.ep_return.to_bits(), b.ep_return.to_bits(), "ep {}", a.episode);
        assert_eq!(a.n_transitions, b.n_transitions, "ep {}", a.episode);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "ep {}", a.episode);
    }
    assert_eq!(seq_params, par_params, "final parameters must be bit-identical");
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use lachesis::policy::net;
    use lachesis::rl::trainer::PjrtTrainBackend;

    const ART: &str = "artifacts";

    fn artifacts_available() -> bool {
        std::path::Path::new(&format!("{ART}/meta.json")).exists()
    }

    fn init_params() -> Vec<f32> {
        params::load_expected(&format!("{ART}/params_init.bin"), net::param_len()).unwrap()
    }

    #[test]
    fn train_step_artifact_updates_parameters() {
        if !artifacts_available() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let init = init_params();
        let backend = PjrtTrainBackend::new(ART, init.clone()).unwrap();
        let batch = backend.batch_size();
        let mut trainer = Trainer::new(quick_cfg(), backend, FeatureMode::Full);
        let stats = trainer.train(batch).unwrap();
        assert_eq!(stats.len(), 3);
        for s in &stats {
            assert!(s.loss.is_finite());
            assert!(s.entropy.is_finite());
            assert!(s.makespan > 0.0);
        }
        assert_ne!(
            trainer.backend.params(),
            &init[..],
            "parameters must move after updates"
        );
    }

    #[test]
    fn imitation_warmstart_reduces_cross_entropy() {
        if !artifacts_available() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let mut expert = RecordingExpert::new(HeftScheduler::new(), FeatureMode::Full);
        let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(6), 11);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(2), 11).generate();
        let mut sim = Simulator::new(cluster, w);
        sim.run(&mut expert).unwrap();
        assert!(!expert.rows.is_empty());

        let mut backend = PjrtTrainBackend::new(ART, init_params()).unwrap();
        let b = backend.batch_size();
        let rows: Vec<_> = expert.rows.drain(..).collect();
        let chunk = &rows[..rows.len().min(b)];
        let mut losses = Vec::new();
        for _ in 0..8 {
            let l = backend.update(chunk, 1e-3, 0.0, 0.0).unwrap();
            losses.push(l[0]);
        }
        assert!(
            losses.last().unwrap() < losses.first().unwrap(),
            "imitation CE should fall: {losses:?}"
        );
    }

    #[test]
    fn training_then_inference_roundtrip_via_files() {
        if !artifacts_available() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let backend = PjrtTrainBackend::new(ART, init_params()).unwrap();
        let batch = backend.batch_size();
        let mut cfg = quick_cfg();
        cfg.episodes = 2;
        let mut trainer = Trainer::new(cfg, backend, FeatureMode::Full);
        trainer.train(batch).unwrap();
        let dir = "/tmp/lachesis_train_roundtrip";
        std::fs::create_dir_all(dir).unwrap();
        let path = format!("{dir}/p.bin");
        params::save_f32(&path, trainer.backend.params()).unwrap();

        use lachesis::runtime::PjrtPolicy;
        let policy = PjrtPolicy::new(ART, Some(&path)).unwrap();
        let mut sched = LachesisScheduler::greedy(Box::new(policy));
        let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(8), 13);
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(3), 13).generate();
        let mut sim = Simulator::new(cluster, w);
        let report = sim.run(&mut sched).unwrap();
        assert!(report.makespan > 0.0);
        sim.state.validate().unwrap();
        std::fs::remove_dir_all(dir).ok();
    }
}
