//! Crash-recovery integration: the write-ahead journal, snapshots, and
//! the restore path must rebuild the agent core bit-for-bit; duplicate
//! request ids must stay exactly-once across a restart; and the server
//! must keep answering — through poisoned cores, torn journal tails,
//! and shutdown racing a flood of in-flight requests.

use lachesis::cluster::Cluster;
use lachesis::config::ClusterConfig;
use lachesis::sched::HighRankUpScheduler;
use lachesis::service::{
    AgentServer, ClientConfig, Durability, Request, Response, ServiceClient, ServiceMode,
};
use lachesis::util::rng::Rng;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "lachesis-recovery-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A server whose scheduler and cluster are fully determined by
/// `(executors, seed)` — reference, journaled, and restored instances
/// built from the same pair are interchangeable.
fn server(executors: usize, seed: u64) -> AgentServer {
    let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(executors), seed);
    AgentServer::with_mode(
        cluster,
        Box::new(HighRankUpScheduler::new()),
        ServiceMode::Batched,
    )
}

fn journaled(
    executors: usize,
    seed: u64,
    dir: &Path,
    snapshot_every: u64,
    restore: bool,
) -> AgentServer {
    server(executors, seed)
        .with_durability(Durability {
            dir: dir.to_path_buf(),
            snapshot_every,
            restore,
        })
        .unwrap()
}

/// A small deterministic tagged request stream: chain-DAG submits (some
/// arriving in the future), heartbeats, failure reports with recovery
/// times, and schedule calls.
fn stream(jobs: usize, executors: usize) -> Vec<(String, Request)> {
    let mut reqs = Vec::new();
    let mut t = 0.0;
    for k in 0..jobs {
        t += 1.5;
        let n = 2 + k % 3;
        reqs.push((
            format!("s{k}-submit"),
            Request::SubmitJob {
                name: format!("job-{k}"),
                // Every third job arrives in the future, exercising the
                // pending heap across snapshot/restore.
                arrival: if k % 3 == 2 { t + 4.0 } else { t },
                computes: (0..n).map(|i| 2.0 + i as f64).collect(),
                edges: (0..n - 1).map(|i| (i, i + 1, 1.0 + i as f64)).collect(),
            },
        ));
        if k > 0 {
            reqs.push((
                format!("s{k}-hb"),
                Request::TaskComplete {
                    job: k - 1,
                    node: 0,
                    time: t,
                },
            ));
        }
        if k % 4 == 1 {
            reqs.push((
                format!("s{k}-fail"),
                Request::ReportFailure {
                    exec: k % executors,
                    time: t,
                    recovery: Some(t + 6.0),
                },
            ));
        }
        reqs.push((format!("s{k}-sched"), Request::Schedule { time: t }));
    }
    reqs
}

fn apply(server: &AgentServer, reqs: &[(String, Request)]) -> Vec<String> {
    reqs.iter()
        .map(|(id, req)| {
            server
                .handle_tagged(Some(id.as_str()), req.clone())
                .to_json()
                .to_string()
        })
        .collect()
}

/// The full core document (sim state, placements, pending/recovery
/// heaps, dedup window) rendered to its canonical JSON string — the
/// bitwise-equality yardstick for every test below.
fn core_fingerprint(server: &AgentServer) -> String {
    server.with_core(|core| core.snapshot_json().to_string())
}

#[test]
fn kill_and_restore_matches_uninterrupted_reference() {
    let dir = tmpdir("restore");
    let reqs = stream(9, 6);
    let kill_at = reqs.len() / 2;

    let reference = server(6, 3);
    let ref_acks = apply(&reference, &reqs);

    // Every ack is released only after its journal record is fsynced, so
    // dropping the server right after an ack is exactly a SIGKILL's view
    // of the disk.
    let first = journaled(6, 3, &dir, 5, false);
    let pre_acks = apply(&first, &reqs[..kill_at]);
    assert_eq!(pre_acks, ref_acks[..kill_at].to_vec());
    drop(first);

    let restored = journaled(6, 3, &dir, 5, true);
    assert_eq!(
        core_fingerprint(&restored),
        {
            let ref_at_kill = server(6, 3);
            apply(&ref_at_kill, &reqs[..kill_at]);
            core_fingerprint(&ref_at_kill)
        },
        "restored core must be bitwise-identical at the kill point"
    );
    let post_acks = apply(&restored, &reqs[kill_at..]);
    assert_eq!(post_acks, ref_acks[kill_at..].to_vec());
    assert_eq!(core_fingerprint(&restored), core_fingerprint(&reference));
    assert_eq!(
        restored.handle(Request::Status).to_json().to_string(),
        reference.handle(Request::Status).to_json().to_string(),
        "final status must be byte-identical"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn torn_journal_tail_is_discarded_on_restore() {
    let dir = tmpdir("torn");
    let reqs = stream(6, 5);
    let reference = server(5, 9);
    apply(&reference, &reqs);

    let first = journaled(5, 9, &dir, 0, false);
    apply(&first, &reqs);
    drop(first);

    // A crash mid-append leaves a torn, newline-less tail. Restore must
    // truncate it and come back with every acknowledged record intact.
    use std::io::Write as _;
    let mut f = std::fs::OpenOptions::new()
        .append(true)
        .open(dir.join(lachesis::service::journal::JOURNAL_FILE))
        .unwrap();
    f.write_all(b"{\"seq\":9999,\"req\":{\"type\":\"schedu").unwrap();
    drop(f);

    let restored = journaled(5, 9, &dir, 0, true);
    assert_eq!(core_fingerprint(&restored), core_fingerprint(&reference));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn duplicate_request_id_is_exactly_once_across_restart() {
    let dir = tmpdir("dedup");
    let submit = Request::SubmitJob {
        name: "only-once".to_string(),
        arrival: 0.0,
        computes: vec![3.0, 1.0],
        edges: vec![(0, 1, 2.0)],
    };
    let first = journaled(4, 1, &dir, 1, false);
    let ack = first.handle_tagged(Some("dup-1"), submit.clone()).to_json().to_string();
    drop(first);

    let restored = journaled(4, 1, &dir, 1, true);
    let retry = restored
        .handle_tagged(Some("dup-1"), submit)
        .to_json()
        .to_string();
    assert_eq!(retry, ack, "retry must be answered byte-identically");
    match restored.handle(Request::Status) {
        Response::Status { jobs, deduped, .. } => {
            assert_eq!(jobs, 1, "the job must not be applied twice");
            assert_eq!(deduped, 1, "the retry must be counted as a duplicate");
        }
        other => panic!("unexpected {other:?}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn reopening_a_used_journal_without_restore_is_refused() {
    let dir = tmpdir("guard");
    let first = journaled(3, 2, &dir, 0, false);
    apply(&first, &stream(2, 3));
    drop(first);
    // Appending new sequence numbers without replaying the old ones
    // would poison any later recovery — the server must refuse.
    let err = server(3, 2)
        .with_durability(Durability {
            dir: dir.clone(),
            snapshot_every: 0,
            restore: false,
        })
        .err()
        .expect("reopening without --restore must fail");
    assert!(format!("{err:#}").contains("--restore"), "got: {err:#}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Hand-rolled property test: random interleavings of submit /
/// schedule / task_complete / report_failure, a crash at a random
/// point, snapshots at a random cadence — the restored core must be
/// bitwise-equal to a reference that never crashed, for every seed.
#[test]
fn replay_property_random_interleavings() {
    for seed in 0..6u64 {
        let dir = tmpdir(&format!("prop{seed}"));
        let executors = 4 + (seed as usize % 3);
        let mut rng = Rng::new(0xC0FFEE ^ (seed * 7919));
        let mut reqs: Vec<(String, Request)> = Vec::new();
        let mut t = 0.0;
        let mut n_jobs = 0usize;
        for i in 0..40 {
            t += rng.exponential(1.0);
            let roll = rng.next_f64();
            let req = if roll < 0.4 || n_jobs == 0 {
                let n = 1 + rng.below(4);
                let job = Request::SubmitJob {
                    name: format!("p{i}"),
                    arrival: if rng.next_f64() < 0.3 {
                        t + 5.0 * rng.next_f64()
                    } else {
                        t
                    },
                    computes: (0..n).map(|_| 1.0 + 3.0 * rng.next_f64()).collect(),
                    edges: (0..n.saturating_sub(1))
                        .map(|u| (u, u + 1, 5.0 * rng.next_f64()))
                        .collect(),
                };
                n_jobs += 1;
                job
            } else if roll < 0.7 {
                Request::Schedule { time: t }
            } else if roll < 0.9 {
                Request::TaskComplete {
                    job: rng.below(n_jobs),
                    node: 0,
                    time: t,
                }
            } else {
                Request::ReportFailure {
                    exec: rng.below(executors),
                    time: t,
                    recovery: if rng.next_f64() < 0.5 {
                        Some(t + 3.0 * rng.next_f64())
                    } else {
                        None
                    },
                }
            };
            reqs.push((format!("p{seed}-{i}"), req));
        }
        let kill_at = 1 + rng.below(reqs.len() - 1);
        let snapshot_every = rng.below(5) as u64; // 0 = journal-only

        let reference = server(executors, seed);
        let ref_acks = apply(&reference, &reqs);

        let first = journaled(executors, seed, &dir, snapshot_every, false);
        apply(&first, &reqs[..kill_at]);
        drop(first);

        let restored = journaled(executors, seed, &dir, snapshot_every, true);
        let post = apply(&restored, &reqs[kill_at..]);
        assert_eq!(
            post,
            ref_acks[kill_at..].to_vec(),
            "seed {seed}: post-restore responses diverged (kill_at {kill_at}, snap {snapshot_every})"
        );
        assert_eq!(
            core_fingerprint(&restored),
            core_fingerprint(&reference),
            "seed {seed}: restored core not bitwise-equal (kill_at {kill_at}, snap {snapshot_every})"
        );
        // The restored schedule function itself must agree, not just the
        // state: one more decision at a later time, byte-for-byte.
        let probe = Request::Schedule { time: t + 10.0 };
        assert_eq!(
            restored.handle(probe.clone()).to_json().to_string(),
            reference.handle(probe).to_json().to_string(),
            "seed {seed}: post-restore decision diverged"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn poisoned_core_still_serves_status_and_shutdown() {
    let agent = Arc::new(server(4, 8));
    let (tx, rx) = std::sync::mpsc::channel();
    let srv = {
        let agent = Arc::clone(&agent);
        std::thread::spawn(move || agent.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()))
    };
    let addr = rx.recv().unwrap().to_string();
    let mut client = ServiceClient::connect(&addr).unwrap();
    assert!(matches!(
        client.call(&Request::Schedule { time: 0.0 }).unwrap(),
        Response::Assignments(_)
    ));

    // Panic while holding the core lock: the mutex is now poisoned.
    let poison = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        agent.with_core(|_| panic!("deliberate poison"))
    }));
    assert!(poison.is_err());

    // Reads must still be answered (batched mode serves them from the
    // lock-free snapshot), mutations must degrade to an error response
    // rather than killing the connection thread, and shutdown must
    // still take the whole server down cleanly.
    assert!(matches!(
        client.call(&Request::Status).unwrap(),
        Response::Status { .. }
    ));
    match client.call(&Request::Schedule { time: 1.0 }).unwrap() {
        Response::Error(msg) => assert!(msg.contains("poisoned"), "got: {msg}"),
        other => panic!("expected an error for a mutation on a poisoned core, got {other:?}"),
    }
    assert!(matches!(
        client.call(&Request::Shutdown).unwrap(),
        Response::Ok { .. }
    ));
    srv.join().unwrap().unwrap();
}

#[test]
fn flood_then_shutdown_answers_every_in_flight_request() {
    let agent = Arc::new(server(4, 4));
    let (tx, rx) = std::sync::mpsc::channel();
    let srv = {
        let agent = Arc::clone(&agent);
        std::thread::spawn(move || agent.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()))
    };
    let addr = rx.recv().unwrap().to_string();

    // Flood from several connections while shutdown races the drain: every
    // request must resolve promptly — applied, refused with an explicit
    // shutting-down error, or a closed connection. Never a hang (the read
    // deadline would surface one as a timeout error instead).
    let cfg = ClientConfig {
        read_timeout: Duration::from_secs(10),
        retries: 0,
        ..ClientConfig::default()
    };
    let floods: Vec<_> = (0..6)
        .map(|f| {
            let addr = addr.clone();
            let cfg = cfg.clone();
            std::thread::spawn(move || -> (usize, usize) {
                let Ok(mut client) = ServiceClient::connect_with(&addr, cfg) else {
                    return (0, 0);
                };
                let (mut applied, mut refused) = (0, 0);
                for k in 0..200 {
                    match client.call(&Request::SubmitJob {
                        name: format!("flood-{f}-{k}"),
                        arrival: 0.0,
                        computes: vec![1.0],
                        edges: vec![],
                    }) {
                        Ok(Response::Ok { .. }) => applied += 1,
                        Ok(Response::Error(msg)) => {
                            assert!(
                                msg.contains("shutting down"),
                                "unexpected error under shutdown: {msg}"
                            );
                            refused += 1;
                            break;
                        }
                        Ok(other) => panic!("unexpected {other:?}"),
                        // Connection torn down by shutdown — also a
                        // resolved outcome.
                        Err(_) => break,
                    }
                }
                (applied, refused)
            })
        })
        .collect();
    std::thread::sleep(Duration::from_millis(30));
    let mut shut = ServiceClient::connect(&addr).unwrap();
    shut.call(&Request::Shutdown).unwrap();
    let mut total_applied = 0;
    for h in floods {
        let (applied, _refused) = h.join().unwrap();
        total_applied += applied;
    }
    srv.join().unwrap().unwrap();
    // The flood must have made real progress before the shutdown landed.
    assert!(total_applied > 0, "flood never applied anything");
}
