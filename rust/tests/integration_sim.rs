//! End-to-end simulator integration: every scheduler completes every
//! workload mode, schedules validate, and metrics behave sanely.

use lachesis::cluster::Cluster;
use lachesis::config::{ClusterConfig, WorkloadConfig};
use lachesis::policy::RustPolicy;
use lachesis::sched::{
    CpopScheduler, DecimaScheduler, FifoScheduler, HeftScheduler, HighRankUpScheduler,
    HrrnScheduler, LachesisScheduler, RandomScheduler, Scheduler, SjfScheduler, TdcaScheduler,
};
use lachesis::sim::Simulator;
use lachesis::workload::WorkloadGenerator;

fn all_schedulers(seed: u64) -> Vec<Box<dyn Scheduler>> {
    vec![
        Box::new(FifoScheduler::new()),
        Box::new(SjfScheduler::new()),
        Box::new(HrrnScheduler::new()),
        Box::new(HighRankUpScheduler::new()),
        Box::new(HeftScheduler::new()),
        Box::new(CpopScheduler::new()),
        Box::new(TdcaScheduler::new()),
        Box::new(RandomScheduler::new(seed)),
        Box::new(DecimaScheduler::greedy_decima(Box::new(RustPolicy::random(
            seed,
        )))),
        Box::new(LachesisScheduler::greedy(Box::new(RustPolicy::random(
            seed ^ 1,
        )))),
    ]
}

#[test]
fn every_scheduler_completes_batch_and_validates() {
    let cfg = ClusterConfig::with_executors(10);
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(5), 42).generate();
    let n_tasks = w.n_tasks();
    for mut sched in all_schedulers(42) {
        let mut sim = Simulator::new(Cluster::heterogeneous(&cfg, 42), w.clone());
        let report = sim
            .run(sched.as_mut())
            .unwrap_or_else(|e| panic!("{} failed: {e}", sched.name()));
        assert_eq!(report.n_tasks, n_tasks);
        assert!(report.makespan > 0.0, "{}", sched.name());
        assert!(report.avg_slr >= 1.0 - 1e-9, "{}: slr < 1", sched.name());
        sim.state
            .validate()
            .unwrap_or_else(|e| panic!("{} invalid: {e}", sched.name()));
    }
}

#[test]
fn every_scheduler_completes_continuous_and_validates() {
    let cfg = ClusterConfig::with_executors(10);
    let w = WorkloadGenerator::new(WorkloadConfig::continuous(6), 7).generate();
    for mut sched in all_schedulers(7) {
        let mut sim = Simulator::new(Cluster::heterogeneous(&cfg, 7), w.clone());
        let report = sim
            .run(sched.as_mut())
            .unwrap_or_else(|e| panic!("{} failed: {e}", sched.name()));
        // No job can complete before it arrives.
        let last_arrival = sim
            .state
            .jobs
            .iter()
            .map(|j| j.arrival)
            .fold(0.0f64, f64::max);
        assert!(report.makespan >= last_arrival, "{}", sched.name());
        sim.state.validate().unwrap();
    }
}

#[test]
fn makespan_at_least_critical_path_bound() {
    // The SLR denominator is a true lower bound: makespan ≥ max_j CP_j and
    // makespan ≥ total_work / Σ v_k (perfect parallelism bound).
    let cfg = ClusterConfig::with_executors(8);
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(4), 11).generate();
    let cluster = Cluster::heterogeneous(&cfg, 11);
    let v_max = cluster.v_max();
    let v_sum: f64 = cluster.executors.iter().map(|e| e.speed).sum();
    let cp_bound = w
        .jobs
        .iter()
        .map(|j| lachesis::dag::graph::critical_path_min(j, v_max).1)
        .fold(0.0f64, f64::max);
    let work_bound = w.total_work() / v_sum;
    for mut sched in all_schedulers(11) {
        let mut sim = Simulator::new(cluster.clone(), w.clone());
        let report = sim.run(sched.as_mut()).unwrap();
        assert!(
            report.makespan >= cp_bound - 1e-9,
            "{}: {} < CP bound {}",
            sched.name(),
            report.makespan,
            cp_bound
        );
        assert!(
            report.makespan >= work_bound - 1e-9,
            "{}: below work conservation bound",
            sched.name()
        );
    }
}

#[test]
fn single_executor_serializes_everything() {
    let cluster = Cluster::homogeneous(1, 2.0, 100.0);
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(3), 13).generate();
    let total = w.total_work();
    let mut sim = Simulator::new(cluster, w);
    let report = sim.run(&mut HeftScheduler::new()).unwrap();
    // One executor, no duplication: makespan == total work / speed.
    assert!((report.makespan - total / 2.0).abs() < 1e-6);
    assert!((report.speedup - 1.0).abs() < 1e-6);
}

#[test]
fn more_executors_never_hurt_heft_much() {
    // Weak monotonicity sanity: 16 executors should beat 2 on a parallel
    // workload (allowing small scheduling noise).
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(8), 17).generate();
    let r2 = Simulator::new(Cluster::homogeneous(2, 2.5, 100.0), w.clone())
        .run(&mut HeftScheduler::new())
        .unwrap();
    let r16 = Simulator::new(Cluster::homogeneous(16, 2.5, 100.0), w)
        .run(&mut HeftScheduler::new())
        .unwrap();
    assert!(
        r16.makespan <= r2.makespan * 1.05,
        "16 exec {} vs 2 exec {}",
        r16.makespan,
        r2.makespan
    );
}

#[test]
fn duplication_count_reported() {
    // On a slow network, DEFT-based schedulers should duplicate at least
    // occasionally across a decent-size workload.
    let mut cfg = ClusterConfig::with_executors(12);
    cfg.comm_mbps = 5.0;
    let w = WorkloadGenerator::new(WorkloadConfig::large_batch(10), 19).generate();
    let mut sim = Simulator::new(Cluster::heterogeneous(&cfg, 19), w);
    let report = sim.run(&mut HighRankUpScheduler::new()).unwrap();
    assert!(
        report.n_duplicates > 0,
        "expected duplication on a 5 MB/s network"
    );
    sim.state.validate().unwrap();
}

#[test]
fn decision_times_recorded_for_every_assignment() {
    let cfg = ClusterConfig::with_executors(6);
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(3), 23).generate();
    let n = w.n_tasks();
    let mut sim = Simulator::new(Cluster::heterogeneous(&cfg, 23), w);
    let report = sim.run(&mut FifoScheduler::new()).unwrap();
    // At least one timing sample per assignment (schedulers may also be
    // polled and pass).
    assert!(report.decision_ms.len() >= n);
}
