//! Network-topology integration suite: the `flat` model must be
//! bit-identical to the pre-refactor scalar communication model across
//! the whole zoo (the golden pin for this subsystem), tree/fat-tree runs
//! must stay `validate()`-clean, topology pricing must actually change
//! placements, and snapshots must pin the topology they were taken under.

use anyhow::Result;
use lachesis::cluster::Cluster;
use lachesis::config::ClusterConfig;
use lachesis::config::WorkloadConfig;
use lachesis::dag::{Job, TaskRef};
use lachesis::net::NetConfig;
use lachesis::policy::RustPolicy;
use lachesis::sched::{
    CpopScheduler, DecimaScheduler, DlsScheduler, FifoScheduler, HeftScheduler,
    HighRankUpScheduler, HrrnScheduler, LachesisScheduler, RandomScheduler, Scheduler,
    SjfScheduler, TdcaScheduler,
};
use lachesis::sim::{Allocation, SimState, Simulator};
use lachesis::workload::{Workload, WorkloadGenerator};

/// Records every decision the wrapped scheduler emits, with the wall time
/// it was made at (same tracing harness as `golden_append`).
struct Tracing<S: Scheduler> {
    inner: S,
    log: Vec<(f64, TaskRef, Allocation)>,
}

impl<S: Scheduler> Tracing<S> {
    fn new(inner: S) -> Self {
        Tracing {
            inner,
            log: Vec::new(),
        }
    }
}

impl<S: Scheduler> Scheduler for Tracing<S> {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn reset(&mut self) {
        self.inner.reset();
        self.log.clear();
    }

    fn step(&mut self, state: &SimState) -> Result<Option<(TaskRef, Allocation)>> {
        let d = self.inner.step(state)?;
        if let Some((t, a)) = d {
            self.log.push((state.wall, t, a));
        }
        Ok(d)
    }
}

/// The pre-refactor communication model, replicated verbatim: transfers
/// are priced by the inline scalar division `data / comm_mbps` (free on
/// the same executor) — no `NetworkModel`, no matrix, no latency term.
/// Replaying the engine's decisions through this model and demanding
/// bit-identical bookings pins the flat `NetworkModel` to the scalar
/// reference for the whole zoo.
struct ScalarRefModel {
    comm_mbps: f64,
    speeds: Vec<f64>,
    jobs: Vec<Job>,
    exec_ready: Vec<f64>,
    placements: Vec<Vec<Vec<(usize, f64)>>>,
    /// Booking log per executor: (task, start, finish, duplicate).
    log: Vec<Vec<(TaskRef, f64, f64, bool)>>,
}

impl ScalarRefModel {
    fn new(cluster: &Cluster, jobs: Vec<Job>) -> ScalarRefModel {
        let n_exec = cluster.len();
        ScalarRefModel {
            comm_mbps: cluster.comm_mbps,
            speeds: (0..n_exec).map(|e| cluster.speed(e)).collect(),
            exec_ready: vec![0.0; n_exec],
            placements: jobs.iter().map(|j| vec![Vec::new(); j.n_tasks()]).collect(),
            log: vec![Vec::new(); n_exec],
            jobs,
        }
    }

    fn data_ready(&self, t: TaskRef, exec: usize) -> f64 {
        let job = &self.jobs[t.job];
        let mut ready = job.arrival;
        for e in &job.parents[t.node] {
            let edge = job.edge_data(e.other, t.node);
            let avail = self.placements[t.job][e.other]
                .iter()
                .map(|&(pe, pf)| {
                    // The scalar model, byte for byte.
                    pf + if pe == exec { 0.0 } else { edge / self.comm_mbps }
                })
                .fold(f64::INFINITY, f64::min);
            if avail > ready {
                ready = avail;
            }
        }
        ready
    }

    fn apply(&mut self, wall: f64, task: TaskRef, alloc: Allocation) -> f64 {
        let exec = alloc.exec();
        let arrival = self.jobs[task.job].arrival;
        if let Allocation::Duplicate { parent, .. } = alloc {
            let p = TaskRef::new(task.job, parent);
            let p_data = self.data_ready(p, exec);
            let start = p_data.max(self.exec_ready[exec]).max(wall).max(arrival);
            let finish = start + self.jobs[p.job].tasks[p.node].compute / self.speeds[exec];
            self.placements[p.job][p.node].push((exec, finish));
            self.exec_ready[exec] = finish;
            self.log[exec].push((p, start, finish, true));
        }
        let data = self.data_ready(task, exec);
        let start = data.max(self.exec_ready[exec]).max(wall).max(arrival);
        let finish = start + self.jobs[task.job].tasks[task.node].compute / self.speeds[exec];
        self.placements[task.job][task.node].push((exec, finish));
        self.exec_ready[exec] = finish;
        self.log[exec].push((task, start, finish, false));
        finish
    }
}

fn zoo(seed: u64) -> Vec<Tracing<Box<dyn Scheduler>>> {
    let scheds: Vec<Box<dyn Scheduler>> = vec![
        Box::new(FifoScheduler::new()),
        Box::new(SjfScheduler::new()),
        Box::new(HrrnScheduler::new()),
        Box::new(HighRankUpScheduler::new()),
        Box::new(HeftScheduler::new()),
        Box::new(CpopScheduler::new()),
        Box::new(DlsScheduler::new()),
        Box::new(TdcaScheduler::new()),
        Box::new(RandomScheduler::new(seed)),
        Box::new(DecimaScheduler::greedy_decima(Box::new(RustPolicy::random(
            seed,
        )))),
        Box::new(LachesisScheduler::greedy(Box::new(RustPolicy::random(
            seed ^ 1,
        )))),
    ];
    scheds.into_iter().map(Tracing::new).collect()
}

/// Primary-copy executor per task, in scan order — the placement
/// signature compared across topologies.
fn primary_execs(state: &SimState) -> Vec<usize> {
    let mut out = Vec::new();
    for (ji, job) in state.jobs.iter().enumerate() {
        for node in 0..job.n_tasks() {
            let exec = state.placements[ji][node]
                .iter()
                .find(|p| !p.duplicate)
                .map(|p| p.exec)
                .unwrap_or(usize::MAX);
            out.push(exec);
        }
    }
    out
}

fn exec_log_bits(state: &SimState) -> Vec<Vec<(usize, usize, u64, u64, bool)>> {
    state
        .exec_log
        .iter()
        .map(|log| {
            log.iter()
                .map(|(t, pl)| {
                    (
                        t.job,
                        t.node,
                        pl.start.to_bits(),
                        pl.finish.to_bits(),
                        pl.duplicate,
                    )
                })
                .collect()
        })
        .collect()
}

/// The golden pin: every zoo scheduler on a flat-topology cluster books
/// bit-identically to the pre-refactor scalar communication model.
#[test]
fn flat_zoo_bitwise_matches_scalar_reference() {
    for seed in [11u64, 42, 99] {
        let mut cfg = ClusterConfig::with_executors(10);
        // The explicit flat config must be the noop it claims to be.
        cfg.net = NetConfig::parse("flat").unwrap();
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(5), seed).generate();
        for mut sched in zoo(seed) {
            let cluster = Cluster::heterogeneous(&cfg, seed);
            let refmodel_jobs = w.jobs.clone();
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            let report = sim.run(&mut sched).unwrap();
            let name = sched.name();
            let mut reference = ScalarRefModel::new(&cluster, refmodel_jobs);
            for &(wall, task, alloc) in &sched.log {
                reference.apply(wall, task, alloc);
            }
            for (e, log) in sim.state.exec_log.iter().enumerate() {
                assert_eq!(
                    log.len(),
                    reference.log[e].len(),
                    "{name}: executor {e} booking count"
                );
                for (i, ((t, pl), &(rt, rs, rf, rd))) in
                    log.iter().zip(&reference.log[e]).enumerate()
                {
                    assert_eq!(*t, rt, "{name}: exec {e} slot {i} task");
                    assert_eq!(pl.duplicate, rd, "{name}: exec {e} slot {i} dup flag");
                    assert_eq!(
                        pl.start.to_bits(),
                        rs.to_bits(),
                        "{name}: exec {e} slot {i} start {} vs {rs}",
                        pl.start
                    );
                    assert_eq!(
                        pl.finish.to_bits(),
                        rf.to_bits(),
                        "{name}: exec {e} slot {i} finish {} vs {rf}",
                        pl.finish
                    );
                }
            }
            let ref_makespan = reference
                .log
                .iter()
                .flatten()
                .filter(|&&(_, _, _, dup)| !dup)
                .map(|&(_, _, f, _)| f)
                .fold(0.0f64, f64::max);
            assert_eq!(
                report.makespan.to_bits(),
                ref_makespan.to_bits(),
                "{name}: makespan"
            );
        }
    }
}

/// The default config (no `net` set anywhere) and an explicit
/// `--net flat` produce bit-identical schedules.
#[test]
fn explicit_flat_is_bitwise_noop() {
    for seed in [7u64, 23] {
        let default_cfg = ClusterConfig::with_executors(8);
        let mut flat_cfg = ClusterConfig::with_executors(8);
        flat_cfg.net = NetConfig::parse("flat").unwrap();
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(4), seed).generate();
        let run = |cfg: &ClusterConfig| {
            let mut sim = Simulator::new(Cluster::heterogeneous(cfg, seed), w.clone());
            sim.run(&mut HeftScheduler::new()).unwrap();
            exec_log_bits(&sim.state)
        };
        assert_eq!(run(&default_cfg), run(&flat_cfg), "seed {seed}");
    }
}

/// Tree and fat-tree runs stay `validate()`-clean for the whole zoo, on
/// batch and continuous workloads.
#[test]
fn topology_zoo_validates() {
    for (spec, n_exec) in [("tree:2x4", 8usize), ("fat-tree:4", 8)] {
        let mut cfg = ClusterConfig::with_executors(n_exec);
        cfg.net = NetConfig::parse(spec).unwrap();
        cfg.validate().unwrap();
        for seed in [5u64, 17] {
            let w =
                WorkloadGenerator::new(WorkloadConfig::small_batch(4), seed).generate();
            for mut sched in zoo(seed) {
                let cluster = Cluster::heterogeneous(&cfg, seed);
                let mut sim = Simulator::new(cluster, w.clone());
                let report = sim
                    .run(&mut sched)
                    .unwrap_or_else(|e| panic!("{spec} {}: {e}", sched.name()));
                assert!(report.makespan.is_finite() && report.makespan > 0.0);
                assert!(sim.state.all_assigned());
                sim.state
                    .validate()
                    .unwrap_or_else(|e| panic!("{spec} {}: {e}", sched.name()));
            }
        }
    }
}

/// The acceptance criterion in miniature: topology-aware transfer
/// pricing makes at least one scheduler place at least one task
/// differently than under flat — locality is visible in decisions, not
/// just in transfer times.
#[test]
fn topologies_change_at_least_one_placement() {
    let mut moved = 0usize;
    for seed in [3u64, 11, 29] {
        let flat_cfg = ClusterConfig::with_executors(8);
        let mut tree_cfg = ClusterConfig::with_executors(8);
        // Narrow uplink (high oversubscription) to make cross-rack
        // pricing bite on the data-heavy small-batch DAGs.
        tree_cfg.net = NetConfig::tree(2, 4);
        tree_cfg.net.oversub = 8.0;
        let w = WorkloadGenerator::new(WorkloadConfig::small_batch(5), seed).generate();
        let run = |cfg: &ClusterConfig| {
            let mut sim = Simulator::new(Cluster::heterogeneous(cfg, seed), w.clone());
            sim.run(&mut HeftScheduler::new()).unwrap();
            primary_execs(&sim.state)
        };
        let flat = run(&flat_cfg);
        let tree = run(&tree_cfg);
        assert_eq!(flat.len(), tree.len());
        moved += flat.iter().zip(&tree).filter(|(a, b)| a != b).count();
    }
    assert!(
        moved > 0,
        "tree pricing never moved a single HEFT placement across 3 seeds"
    );
}

/// Snapshots pin the topology they were taken under: restoring with the
/// same net round-trips bitwise, restoring under a different one fails
/// loudly (pointing at the `--net` flag).
#[test]
fn snapshot_pins_network_topology() {
    let mut tree_cfg = ClusterConfig::with_executors(6);
    tree_cfg.net = NetConfig::tree(2, 3);
    let seed = 13u64;
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(3), seed).generate();
    let mut sim = Simulator::new(Cluster::heterogeneous(&tree_cfg, seed), w);
    sim.run(&mut HeftScheduler::new()).unwrap();
    let snap = sim.state.snapshot_json();

    // Same topology: restores, bit-identical bookings.
    let restored =
        SimState::from_snapshot_json(Cluster::heterogeneous(&tree_cfg, seed), &snap).unwrap();
    assert_eq!(exec_log_bits(&sim.state), exec_log_bits(&restored));
    restored.validate().unwrap();

    // Different topology (flat): must be rejected, naming the fix.
    let flat_cfg = ClusterConfig::with_executors(6);
    let err = SimState::from_snapshot_json(Cluster::heterogeneous(&flat_cfg, seed), &snap)
        .unwrap_err()
        .to_string();
    assert!(err.contains("--net"), "error should point at --net: {err}");

    // Same topology but different knobs: also a different network.
    let mut knob_cfg = tree_cfg.clone();
    knob_cfg.net.oversub = 4.0;
    assert!(
        SimState::from_snapshot_json(Cluster::heterogeneous(&knob_cfg, seed), &snap).is_err(),
        "oversubscription changes transfer times; restore must refuse"
    );
}

/// CLI-facing parse surface: accepted specs, rejected specs, and the
/// capacity check against the executor count.
#[test]
fn net_spec_parse_and_capacity() {
    assert!(NetConfig::parse("flat").unwrap().is_flat());
    assert_eq!(NetConfig::parse("tree:3x4").unwrap().topology_str(), "tree:3x4");
    assert_eq!(
        NetConfig::parse("fat-tree:8").unwrap().topology_str(),
        "fat-tree:8"
    );
    for bad in ["mesh", "tree:3", "tree:ax4", "fat-tree:x"] {
        assert!(NetConfig::parse(bad).is_err(), "'{bad}' must be rejected");
    }
    // Structurally invalid topologies parse but fail validation.
    for degenerate in ["tree:0x4", "fat-tree:3", "fat-tree:0"] {
        let net = NetConfig::parse(degenerate).unwrap();
        assert!(
            net.validate(1).is_err(),
            "'{degenerate}' must fail validation"
        );
    }
    // tree:2x3 holds 6 executors — 7 must fail ClusterConfig validation.
    let mut cfg = ClusterConfig::with_executors(7);
    cfg.net = NetConfig::tree(2, 3);
    assert!(cfg.validate().is_err(), "over-capacity topology accepted");
    cfg.n_executors = 6;
    cfg.validate().unwrap();
}
