//! Hand-computed schedules: small DAGs where the optimal/expected
//! behaviour of each heuristic can be verified against pencil-and-paper
//! timelines (the style of worked example in the HEFT paper).

use lachesis::cluster::Cluster;
use lachesis::dag::{Job, TaskRef};
use lachesis::sched::deft::{cpeft, deft};
use lachesis::sched::eft::{best_eft, eft};
use lachesis::sched::{
    CpopScheduler, FifoScheduler, HeftScheduler, HighRankUpScheduler, SjfScheduler,
    TdcaScheduler,
};
use lachesis::sim::{Allocation, SimState, Simulator};
use lachesis::workload::Workload;

/// Cluster: e0 = 1 GHz, e1 = 2 GHz, link 10 MB/s.
fn cluster() -> Cluster {
    let mut c = Cluster::homogeneous(2, 1.0, 10.0);
    c.executors[1].speed = 2.0;
    c
}

/// Fork-join: 0 → {1, 2} → 3. w = [2, 6, 6, 2]; all edges 10 MB (1 s).
fn fork_join() -> Job {
    Job::new(
        0,
        "forkjoin",
        0.0,
        vec![2.0, 6.0, 6.0, 2.0],
        &[(0, 1, 10.0), (0, 2, 10.0), (1, 3, 10.0), (2, 3, 10.0)],
    )
}

#[test]
fn heft_fork_join_hand_timeline() {
    let w = Workload::new(vec![fork_join()]);
    let mut sim = Simulator::new(cluster(), w);
    let report = sim.run(&mut HeftScheduler::new()).unwrap();
    // HEFT hand timeline: node 0 → e1 (finish 2/2 = 1). First child → e1
    // (local data, start 1, finish 1+6/2 = 4; e0 would be 2+6 = 8).
    // Second child: e1 again (start 4, finish 7) beats e0 (start 2,
    // finish 8). Node 3: e1 local, start 7, finish 7+2/2 = 8; e0 would be
    // max(7+1, arrival) + 2 = 10. Makespan = 8.
    assert!((report.makespan - 8.0).abs() < 1e-9, "{}", report.makespan);
    sim.state.validate().unwrap();
}

#[test]
fn deft_beats_eft_on_communication_heavy_join() {
    // chain with a huge edge: duplication saves the transfer.
    let job = Job::new(0, "heavy", 0.0, vec![2.0, 4.0], &[(0, 1, 100.0)]);
    let w = Workload::new(vec![job]);
    // EFT-only (HEFT):
    let r_eft = Simulator::new(cluster(), w.clone())
        .run(&mut HeftScheduler::new())
        .unwrap();
    // DEFT (same selector):
    let r_deft = Simulator::new(cluster(), w)
        .run(&mut HighRankUpScheduler::new())
        .unwrap();
    // Hand check: node0 → e1 (finish 1). EFT for node1: e1 no-comm →
    // 1 + 2 = 3. DEFT can't beat 3 (dup on e1: 1+1+2 = 4). Both equal
    // here — so makespans match; now force the parent onto e0:
    assert!(r_deft.makespan <= r_eft.makespan + 1e-9);

    // Scripted state to force duplication:
    let job = Job::new(0, "heavy2", 0.0, vec![2.0, 4.0], &[(0, 1, 100.0)]);
    let mut st = SimState::new(cluster(), Workload::new(vec![job]));
    st.mark_arrived(0);
    st.apply(TaskRef::new(0, 0), Allocation::Direct { exec: 0 }); // AFT 2 @ e0
    let t1 = TaskRef::new(0, 1);
    // EFT: e0 → 2 + 4 = 6; e1 → (2 + 10) + 2 = 14 → best 6.
    assert_eq!(best_eft(&st, t1), (0, 6.0));
    // CPEFT on e1: dup 0 (start 0, finish 1), task 1 + 2 = 3.
    assert_eq!(cpeft(&st, t1, 0, 1), 3.0);
    let (alloc, f) = deft(&st, t1);
    assert_eq!(alloc, Allocation::Duplicate { exec: 1, parent: 0 });
    assert_eq!(f, 3.0);
}

#[test]
fn eft_math_matches_simulator_for_all_executors() {
    // For every (task, executor), predicted EFT must equal the finish the
    // simulator produces when forced to that executor.
    let job = fork_join();
    for exec_seq in [[0, 1, 0, 1], [1, 1, 1, 1], [0, 0, 1, 0]] {
        let mut st = SimState::new(cluster(), Workload::new(vec![job.clone()]));
        st.mark_arrived(0);
        // fork_join topo order is 0,1,2,3.
        for (node, &e) in exec_seq.iter().enumerate() {
            let t = TaskRef::new(0, node);
            let predicted = eft(&st, t, e);
            let actual = st.apply(t, Allocation::Direct { exec: e });
            assert!(
                (predicted - actual).abs() < 1e-9,
                "node {node} exec {e}: {predicted} vs {actual}"
            );
        }
        st.validate().unwrap();
    }
}

#[test]
fn cpop_pins_critical_path() {
    // Chain + slack branch; the chain is critical and must go to e1 (2 GHz).
    let job = Job::new(
        0,
        "cp",
        0.0,
        vec![4.0, 4.0, 4.0, 0.1],
        &[(0, 1, 1.0), (1, 2, 1.0), (0, 3, 1.0)],
    );
    let w = Workload::new(vec![job]);
    let mut sim = Simulator::new(cluster(), w);
    sim.run(&mut CpopScheduler::new()).unwrap();
    for node in [0, 1, 2] {
        assert_eq!(
            sim.state.placements[0][node][0].exec, 1,
            "critical node {node} not on CP processor"
        );
    }
}

#[test]
fn tdca_single_chain_one_executor_no_comm() {
    let job = Job::new(
        0,
        "chain",
        0.0,
        vec![2.0, 2.0, 2.0],
        &[(0, 1, 50.0), (1, 2, 50.0)],
    );
    let w = Workload::new(vec![job]);
    let mut sim = Simulator::new(cluster(), w);
    let report = sim.run(&mut TdcaScheduler::new()).unwrap();
    // Whole chain on the 2 GHz executor: 3 × 2/2 = 3 s, no transfers.
    assert!((report.makespan - 3.0).abs() < 1e-9, "{}", report.makespan);
}

#[test]
fn sjf_finishes_short_job_first() {
    let big = Job::new(0, "big", 0.0, vec![50.0, 50.0], &[(0, 1, 1.0)]);
    let small = Job::new(1, "small", 0.0, vec![1.0], &[]);
    let w = Workload::new(vec![big, small]);
    let mut sim = Simulator::new(cluster(), w);
    sim.run(&mut SjfScheduler::new()).unwrap();
    let small_done = sim.state.job_completion(1);
    let big_done = sim.state.job_completion(0);
    assert!(small_done < big_done);
    // The small job was selected first so it starts at t=0 on some
    // executor.
    assert!(small_done <= 1.0 + 1e-9);
}

#[test]
fn fifo_respects_arrival_order_in_continuous_mode() {
    let j0 = Job::new(0, "first", 0.0, vec![10.0], &[]);
    let j1 = Job::new(1, "second", 1.0, vec![1.0], &[]);
    let w = Workload::new(vec![j0, j1]);
    let mut sim = Simulator::new(cluster(), w);
    sim.run(&mut FifoScheduler::new()).unwrap();
    let p0 = sim.state.placements[0][0][0];
    let p1 = sim.state.placements[1][0][0];
    // First job grabbed the fast executor at t=0; second job runs
    // without waiting for the first (free executor 0).
    assert_eq!(p0.exec, 1);
    assert!(p0.start < p1.start);
}
