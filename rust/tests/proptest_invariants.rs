//! Property-based tests: random DAGs + random clusters, checking the
//! schedule invariants every algorithm must preserve. (proptest is not in
//! the offline registry; this uses our seeded generators with explicit
//! case counts — same methodology, deterministic by construction.)

use lachesis::cluster::Cluster;
use lachesis::config::ClusterConfig;
use lachesis::dag::{Job, TaskRef};
use lachesis::policy::RustPolicy;
use lachesis::sched::deft::deft;
use lachesis::sched::eft::best_eft;
use lachesis::sched::{
    CpopScheduler, DecimaScheduler, FifoScheduler, HeftScheduler, HighRankUpScheduler,
    HrrnScheduler, LachesisScheduler, RandomScheduler, Scheduler, SjfScheduler, TdcaScheduler,
};
use lachesis::sim::{Allocation, SimState, Simulator};
use lachesis::util::rng::Rng;
use lachesis::workload::Workload;

/// Random layered DAG: guaranteed acyclic (edges only go to later layers).
fn random_job(rng: &mut Rng, id: usize, arrival: f64) -> Job {
    let n_layers = rng.range_u(1, 5);
    let mut layer_of: Vec<usize> = Vec::new();
    for l in 0..n_layers {
        for _ in 0..rng.range_u(1, 4) {
            layer_of.push(l);
        }
    }
    let n = layer_of.len();
    let computes: Vec<f64> = (0..n).map(|_| rng.range_f(0.5, 20.0)).collect();
    let mut edges = Vec::new();
    for u in 0..n {
        for v in 0..n {
            if layer_of[u] < layer_of[v] && rng.chance(0.35) {
                edges.push((u, v, rng.range_f(0.0, 50.0)));
            }
        }
    }
    Job::new(id, format!("rand{id}"), arrival, computes, &edges)
}

fn random_workload(rng: &mut Rng, n_jobs: usize, continuous: bool) -> Workload {
    let mut t = 0.0;
    let jobs = (0..n_jobs)
        .map(|i| {
            let arrival = if continuous && i > 0 {
                t += rng.exponential(20.0);
                t
            } else {
                0.0
            };
            random_job(rng, i, arrival)
        })
        .collect();
    Workload::new(jobs)
}

fn random_cluster(rng: &mut Rng) -> Cluster {
    let mut cfg = ClusterConfig::with_executors(rng.range_u(1, 12));
    cfg.comm_mbps = rng.range_f(5.0, 500.0);
    Cluster::heterogeneous(&cfg, rng.next_u64())
}

const CASES: u64 = 25;

#[test]
fn prop_all_schedulers_produce_valid_schedules() {
    for case in 0..CASES {
        let mut rng = Rng::new(900 + case);
        let n_jobs = rng.range_u(1, 5);
        let w = random_workload(&mut rng, n_jobs, case % 2 == 0);
        let cluster = random_cluster(&mut rng);
        let mut scheds: Vec<Box<dyn Scheduler>> = vec![
            Box::new(FifoScheduler::new()),
            Box::new(HighRankUpScheduler::new()),
            Box::new(HeftScheduler::new()),
            Box::new(TdcaScheduler::new()),
            Box::new(CpopScheduler::new()),
            Box::new(LachesisScheduler::greedy(Box::new(RustPolicy::random(
                case,
            )))),
        ];
        for sched in scheds.iter_mut() {
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            let report = sim
                .run(sched.as_mut())
                .unwrap_or_else(|e| panic!("case {case} {}: {e}", sched.name()));
            sim.state
                .validate()
                .unwrap_or_else(|e| panic!("case {case} {}: {e}", sched.name()));
            assert!(report.makespan.is_finite() && report.makespan > 0.0);
        }
    }
}

#[test]
fn prop_deft_never_worse_than_eft_pointwise() {
    // At every decision point of a random rollout, DEFT's predicted finish
    // ≤ best EFT (Eq 11 is a min over a superset).
    for case in 0..CASES {
        let mut rng = Rng::new(1700 + case);
        let w = random_workload(&mut rng, 2, false);
        let cluster = random_cluster(&mut rng);
        let mut st = SimState::new(cluster, w);
        for j in 0..st.jobs.len() {
            st.mark_arrived(j);
        }
        while !st.executable().is_empty() {
            let t = st.executable()[rng.below(st.executable().len())];
            let (_, f_eft) = best_eft(&st, t);
            let (alloc, f_deft) = deft(&st, t);
            assert!(
                f_deft <= f_eft + 1e-9,
                "case {case}: DEFT {f_deft} > EFT {f_eft}"
            );
            let actual = st.apply(t, alloc);
            assert!(
                (actual - f_deft).abs() < 1e-6,
                "case {case}: predicted {f_deft} actual {actual}"
            );
        }
        st.validate().unwrap();
    }
}

#[test]
fn prop_child_starts_after_parent_data_arrives() {
    for case in 0..CASES {
        let mut rng = Rng::new(2600 + case);
        let w = random_workload(&mut rng, 3, true);
        let cluster = random_cluster(&mut rng);
        let mut sim = Simulator::new(cluster, w);
        sim.run(&mut HighRankUpScheduler::new()).unwrap();
        let st = &sim.state;
        for (ji, job) in st.jobs.iter().enumerate() {
            for node in 0..job.n_tasks() {
                for pl in &st.placements[ji][node] {
                    for e in &job.parents[node] {
                        let avail = st.parent_data_at(TaskRef::new(ji, node), e.other, pl.exec);
                        assert!(
                            pl.start + 1e-6 >= avail,
                            "case {case}: ({ji},{node}) starts before parent {} data",
                            e.other
                        );
                    }
                }
            }
        }
    }
}

/// Random interleavings of scheduling decisions, crashes (transient and
/// permanent), early recoveries, straggles and wall advances: after
/// every single operation, each incremental cache (frontier, `min_aft`,
/// per-job counters, timeline↔log agreement including blackouts) must
/// equal its scan-based definition — `validate()` is the oracle.
#[test]
fn prop_fault_recovery_keeps_caches_coherent() {
    for case in 0..CASES {
        let mut rng = Rng::new(4200 + case);
        let w = random_workload(&mut rng, 2, false);
        let cluster = random_cluster(&mut rng);
        let mut st = SimState::new(cluster, w);
        for j in 0..st.jobs.len() {
            st.mark_arrived(j);
        }
        let mut wall = 0.0f64;
        for step in 0..60 {
            match rng.below(8) {
                0..=4 => {
                    // Book a random executable task on a random live
                    // executor (the engine's legal-decision contract).
                    let frontier = st.executable().to_vec();
                    let avail: Vec<usize> = (0..st.cluster.len())
                        .filter(|&e| st.exec_available(e))
                        .collect();
                    if frontier.is_empty() || avail.is_empty() {
                        continue;
                    }
                    let t = frontier[rng.below(frontier.len())];
                    let e = avail[rng.below(avail.len())];
                    let f = st.apply(t, Allocation::Direct { exec: e });
                    if rng.chance(0.3) {
                        wall = wall.max(f);
                        st.advance_wall(wall);
                    }
                }
                5 => {
                    let e = rng.below(st.cluster.len());
                    if st.exec_available(e) {
                        let recovery = if rng.chance(0.5) {
                            Some(wall + rng.range_f(1.0, 20.0))
                        } else {
                            None
                        };
                        st.apply_crash(e, wall, recovery);
                    } else if rng.chance(0.5) {
                        st.mark_executor_up(e);
                    }
                }
                6 => {
                    let e = rng.below(st.cluster.len());
                    st.apply_straggle(e, wall, rng.range_f(1.0, 4.0));
                }
                _ => {
                    wall += rng.range_f(0.0, 5.0);
                    st.advance_wall(wall);
                }
            }
            st.validate()
                .unwrap_or_else(|e| panic!("case {case} step {step}: {e}"));
        }
    }
}

#[test]
fn prop_speedup_bounded_by_cluster_capacity() {
    // speedup = seq_time / makespan ≤ Σ v_k / v_max (work conservation).
    for case in 0..CASES {
        let mut rng = Rng::new(3500 + case);
        let w = random_workload(&mut rng, 4, false);
        let cluster = random_cluster(&mut rng);
        let cap: f64 =
            cluster.executors.iter().map(|e| e.speed).sum::<f64>() / cluster.v_max();
        let mut sim = Simulator::new(cluster, w);
        let report = sim.run(&mut HeftScheduler::new()).unwrap();
        assert!(
            report.speedup <= cap + 1e-9,
            "case {case}: speedup {} > capacity {cap}",
            report.speedup
        );
    }
}

#[test]
fn prop_trace_roundtrip_preserves_schedules() {
    // Serializing a workload and re-running the same scheduler must give
    // the identical makespan (determinism + lossless trace).
    for case in 0..10 {
        let mut rng = Rng::new(4400 + case);
        let w = random_workload(&mut rng, 3, true);
        let cluster = random_cluster(&mut rng);
        let json = lachesis::workload::trace::to_json(&w);
        let w2 = lachesis::workload::trace::from_json(&json).unwrap();
        let r1 = Simulator::new(cluster.clone(), w)
            .run(&mut HeftScheduler::new())
            .unwrap();
        let r2 = Simulator::new(cluster, w2)
            .run(&mut HeftScheduler::new())
            .unwrap();
        assert_eq!(r1.makespan, r2.makespan, "case {case}");
    }
}

/// After every `apply`, the incremental frontier must equal the
/// recomputed-from-scratch executable set, and the cached `min_aft` /
/// `left_tasks` / `left_work` must equal their scan-based definitions —
/// including under DEFT duplications and continuous arrivals.
#[test]
fn prop_incremental_caches_match_scan_definitions() {
    for case in 0..CASES {
        let mut rng = Rng::new(6200 + case);
        let w = random_workload(&mut rng, 3, case % 2 == 0);
        let cluster = random_cluster(&mut rng);
        let mut st = SimState::new(cluster, w);
        for j in 0..st.jobs.len() {
            st.mark_arrived(j);
            assert_eq!(
                st.executable(),
                st.executable_scan().as_slice(),
                "case {case}: frontier after arrival"
            );
        }
        while !st.executable().is_empty() {
            let t = st.executable()[rng.below(st.executable().len())];
            // Mix DEFT decisions (which duplicate) with arbitrary ones.
            let alloc = if rng.chance(0.5) {
                deft(&st, t).0
            } else {
                Allocation::Direct {
                    exec: rng.below(st.cluster.len()),
                }
            };
            st.apply(t, alloc);
            assert_eq!(
                st.executable(),
                st.executable_scan().as_slice(),
                "case {case}: frontier after apply"
            );
            for (ji, job) in st.jobs.iter().enumerate() {
                assert_eq!(
                    st.job_left_tasks(ji),
                    st.job_left_tasks_scan(ji),
                    "case {case}: left_tasks job {ji}"
                );
                let (lw, lws) = (st.job_left_work(ji), st.job_left_work_scan(ji));
                assert!(
                    (lw - lws).abs() <= 1e-6 * (1.0 + lws.abs()),
                    "case {case}: left_work job {ji}: {lw} vs {lws}"
                );
                for node in 0..job.n_tasks() {
                    let tr = TaskRef::new(ji, node);
                    let (c, s) = (st.min_aft(tr), st.min_aft_scan(tr));
                    assert!(
                        c == s || (c.is_infinite() && s.is_infinite()),
                        "case {case}: min_aft ({ji},{node}): {c} vs {s}"
                    );
                }
            }
        }
        st.validate().unwrap();
    }
}

/// Gap-aware schedules still satisfy every schedule invariant
/// (`SimState::validate`: exclusivity, arrival/data readiness, timeline =
/// log, caches = scans), and the per-probe gap start never exceeds the
/// append start.
#[test]
fn prop_gap_aware_schedules_validate() {
    use lachesis::config::SchedMode;
    for case in 0..CASES {
        let mut rng = Rng::new(7100 + case);
        let n_jobs = rng.range_u(1, 5);
        let w = random_workload(&mut rng, n_jobs, case % 2 == 1);
        let cluster = random_cluster(&mut rng).with_sched_mode(SchedMode::GapAware);
        let mut scheds: Vec<Box<dyn Scheduler>> = vec![
            Box::new(HeftScheduler::new()),
            Box::new(HighRankUpScheduler::new()),
            Box::new(TdcaScheduler::new()),
        ];
        for sched in scheds.iter_mut() {
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            let report = sim
                .run(sched.as_mut())
                .unwrap_or_else(|e| panic!("case {case} {}: {e}", sched.name()));
            assert!(report.makespan.is_finite() && report.makespan > 0.0);
            sim.state
                .validate()
                .unwrap_or_else(|e| panic!("case {case} {}: {e}", sched.name()));
        }
    }
}

/// Pointwise dominance: for any (task, executor) probe in any reachable
/// state, the gap-aware start is never later than the append start (the
/// gap walk's fall-through is bounded by max(ready, tail)).
#[test]
fn prop_gap_start_never_later_than_append() {
    for case in 0..CASES {
        let mut rng = Rng::new(8000 + case);
        let w = random_workload(&mut rng, 2, false);
        let cluster = random_cluster(&mut rng);
        let mut st = SimState::new(cluster, w);
        for j in 0..st.jobs.len() {
            st.mark_arrived(j);
        }
        while !st.executable().is_empty() {
            let t = st.executable()[rng.below(st.executable().len())];
            for e in 0..st.cluster.len() {
                let ready = st.ready_time(t, e);
                let dur = st.jobs[t.job].tasks[t.node].compute / st.cluster.speed(e);
                let gap = st.timeline(e).earliest_gap(ready, dur);
                let append = ready.max(st.exec_ready(e));
                assert!(
                    gap <= append + 1e-9,
                    "case {case}: gap start {gap} > append {append}"
                );
            }
            let exec = rng.below(st.cluster.len());
            st.apply(t, Allocation::Direct { exec });
        }
    }
}

#[test]
fn prop_encoding_masks_consistent() {
    use lachesis::policy::encode::encode;
    use lachesis::policy::features::FeatureMode;
    for case in 0..CASES {
        let mut rng = Rng::new(5300 + case);
        let w = random_workload(&mut rng, 3, false);
        let cluster = random_cluster(&mut rng);
        let mut st = SimState::new(cluster, w);
        for j in 0..st.jobs.len() {
            st.mark_arrived(j);
        }
        // Walk a partial schedule, re-encoding along the way.
        for _ in 0..6 {
            let enc = encode(&st, FeatureMode::Full);
            // exec_mask ⊆ node_mask; used slots have node_mask 1.
            for i in 0..enc.variant.n {
                if enc.exec_mask[i] > 0.0 {
                    assert!(enc.node_mask[i] > 0.0, "case {case}: exec w/o node");
                }
                if i < enc.n_used() {
                    assert!(enc.node_mask[i] > 0.0);
                } else {
                    assert!(enc.node_mask[i] == 0.0);
                }
            }
            assert_eq!(enc.n_executable(), st.executable().len().min(enc.n_used()));
            if st.executable().is_empty() {
                break;
            }
            let t = st.executable()[0];
            let exec = rng.below(st.cluster.len());
            st.apply(t, Allocation::Direct { exec });
        }
    }
}

/// The CSR-sparse forward pass must agree with the dense-from-scratch
/// oracle (the computation the PJRT artifact performs) on random
/// workloads, feature modes and shape variants, at every point of a
/// partial schedule.
#[test]
fn prop_sparse_forward_matches_dense_oracle() {
    use lachesis::policy::encode::encode;
    use lachesis::policy::features::FeatureMode;
    for case in 0..CASES {
        let mut rng = Rng::new(6100 + case);
        let n_jobs = 1 + (case as usize % 12); // spans the N=64 and N=256 variants
        let w = random_workload(&mut rng, n_jobs, false);
        let cluster = random_cluster(&mut rng);
        let mut st = SimState::new(cluster, w);
        for j in 0..st.jobs.len() {
            st.mark_arrived(j);
        }
        let mut net = RustPolicy::random(6100 + case);
        for _ in 0..5 {
            for mode in [FeatureMode::Full, FeatureMode::HomogeneousBlind] {
                let enc = encode(&st, mode);
                let (ls, vs) = net.forward(&enc);
                let (ld, vd) = net.forward_dense(&enc);
                assert!(
                    (vs - vd).abs() <= 1e-5,
                    "case {case}: value sparse {vs} vs dense {vd}"
                );
                for i in 0..enc.n_used() {
                    assert!(
                        (ls[i] - ld[i]).abs() <= 1e-5,
                        "case {case} slot {i}: sparse {} vs dense {}",
                        ls[i],
                        ld[i]
                    );
                }
            }
            if st.executable().is_empty() {
                break;
            }
            let t = st.executable()[rng.below(st.executable().len())];
            let exec = rng.below(st.cluster.len());
            st.apply(t, Allocation::Direct { exec });
        }
    }
}

/// After an arbitrary replayable event sequence — assignments (direct and
/// duplicating), monotone wall advances across copy-finish boundaries,
/// staggered arrivals — the incremental `EncoderCache` must return an
/// encoding bitwise identical to a from-scratch `encode()`.
#[test]
fn prop_encoder_cache_matches_fresh_encode() {
    use lachesis::policy::encode::encode;
    use lachesis::policy::features::FeatureMode;
    use lachesis::policy::EncoderCache;
    for case in 0..CASES {
        let mut rng = Rng::new(6200 + case);
        let n_jobs = 1 + (case as usize % 10); // > 8 jobs forces the N=256 variant
        let continuous = case % 2 == 0;
        let w = random_workload(&mut rng, n_jobs, continuous);
        let cluster = random_cluster(&mut rng);
        let mut st = SimState::new(cluster, w);
        for j in 0..st.jobs.len() {
            if st.jobs[j].arrival <= st.wall {
                st.mark_arrived(j);
            }
        }
        let mut cache = EncoderCache::new(FeatureMode::Full);
        let mut guard = 0;
        loop {
            let fresh = encode(&st, FeatureMode::Full);
            let cached = cache.refresh(&st);
            assert_eq!(cached, &fresh, "case {case} step {guard}");
            if st.all_assigned() {
                break;
            }
            if st.executable().is_empty() {
                // Advance the wall to the next arrival (engine-style).
                let next = (0..st.jobs.len())
                    .filter(|&j| !st.arrived[j])
                    .map(|j| st.jobs[j].arrival)
                    .fold(f64::INFINITY, f64::min);
                assert!(next.is_finite(), "case {case}: no runnable work left");
                st.wall = st.wall.max(next);
                for j in 0..st.jobs.len() {
                    if !st.arrived[j] && st.jobs[j].arrival <= st.wall {
                        st.mark_arrived(j);
                    }
                }
                continue;
            }
            let t = st.executable()[rng.below(st.executable().len())];
            let exec = rng.below(st.cluster.len());
            let parents = &st.jobs[t.job].parents[t.node];
            let finish = if !parents.is_empty() && rng.chance(0.3) {
                let parent = parents[rng.below(parents.len())].other;
                st.apply(t, Allocation::Duplicate { exec, parent })
            } else {
                st.apply(t, Allocation::Direct { exec })
            };
            if rng.chance(0.5) {
                // Monotone wall advance: sometimes exactly onto a finish
                // boundary, sometimes past it by a random amount.
                let bump = if rng.chance(0.5) {
                    finish
                } else {
                    st.wall + rng.range_f(0.0, 10.0)
                };
                if bump > st.wall {
                    st.wall = bump;
                }
                for j in 0..st.jobs.len() {
                    if !st.arrived[j] && st.jobs[j].arrival <= st.wall {
                        st.mark_arrived(j);
                    }
                }
            }
            if rng.chance(0.1) {
                // Compaction may drop events the cache has not replayed
                // yet — it must detect the gap and rebuild, still bitwise.
                st.compact_enc_log();
            }
            guard += 1;
            assert!(guard < 10_000, "case {case}: runaway episode");
        }
    }
}

/// The batched block-CSR forward must agree with the per-state sparse
/// forward on every packed state — across random workloads, partial
/// schedules, both feature modes, and mixed shape variants inside one
/// batch (the packer keeps only used rows, so N=64 and N=256 states can
/// share a batch).
#[test]
fn prop_forward_batch_matches_single_state() {
    use lachesis::policy::encode::encode;
    use lachesis::policy::features::FeatureMode;
    use lachesis::policy::PackedBatch;
    for case in 0..CASES {
        let mut rng = Rng::new(9400 + case);
        for mode in [FeatureMode::Full, FeatureMode::HomogeneousBlind] {
            // Collect snapshots of several independent partial schedules,
            // deliberately spanning both shape variants.
            let mut encs = Vec::new();
            for s in 0..3u64 {
                let n_jobs = 1 + ((case + s) as usize % 12);
                let w = random_workload(&mut rng, n_jobs, false);
                let cluster = random_cluster(&mut rng);
                let mut st = SimState::new(cluster, w);
                for j in 0..st.jobs.len() {
                    st.mark_arrived(j);
                }
                encs.push(encode(&st, mode));
                for _ in 0..3 {
                    if st.executable().is_empty() {
                        break;
                    }
                    let t = st.executable()[rng.below(st.executable().len())];
                    let exec = rng.below(st.cluster.len());
                    st.apply(t, Allocation::Direct { exec });
                    let enc = encode(&st, mode);
                    if enc.n_used() > 0 {
                        encs.push(enc);
                    }
                }
            }
            let mut net = RustPolicy::random(9400 + case);
            let refs: Vec<&_> = encs.iter().collect();
            let batch = PackedBatch::pack(&refs);
            let (mut logits, mut values) = (Vec::new(), Vec::new());
            net.forward_batch(&batch, &mut logits, &mut values);
            assert_eq!(values.len(), encs.len(), "case {case}");
            let mut single = Vec::new();
            for (bi, enc) in encs.iter().enumerate() {
                let v = net.forward_into(enc, &mut single);
                assert!(
                    (values[bi] - v).abs() <= 1e-5,
                    "case {case} state {bi}: batched value {} vs single {v}",
                    values[bi]
                );
                let rows = batch.state_rows(&logits, bi);
                assert_eq!(rows.len(), enc.n_used(), "case {case} state {bi}");
                for i in 0..enc.n_used() {
                    assert!(
                        (rows[i] - single[i]).abs() <= 1e-5,
                        "case {case} state {bi} slot {i}: batched {} vs single {}",
                        rows[i],
                        single[i]
                    );
                }
            }
        }
    }
}

/// Random cluster with a random topology (flat, tree, fat-tree), always
/// sized so the executor count fits the topology's capacity.
fn random_net_cluster(rng: &mut Rng) -> Cluster {
    use lachesis::net::NetConfig;
    let n = rng.range_u(2, 24);
    let mut cfg = ClusterConfig::with_executors(n);
    cfg.comm_mbps = rng.range_f(5.0, 500.0);
    cfg.net = match rng.below(3) {
        0 => NetConfig::flat(),
        1 => {
            let racks = rng.range_u(1, 5);
            NetConfig::tree(racks, (n + racks - 1) / racks)
        }
        _ => {
            let mut k = 2 * rng.range_u(1, 5);
            while k * k * k / 4 < n {
                k += 2;
            }
            NetConfig::fat_tree(k)
        }
    };
    cfg.validate().unwrap();
    Cluster::heterogeneous(&cfg, rng.next_u64())
}

/// Network-model invariants on random topologies: bandwidth and latency
/// are bitwise symmetric, self-transfer is free (infinite bandwidth,
/// zero latency), and a rack-local link is never slower than any
/// cross-rack link — in bandwidth or in latency.
#[test]
fn prop_network_symmetric_self_free_local_fastest() {
    for case in 0..CASES {
        let mut rng = Rng::new(11_000 + case);
        let cluster = random_net_cluster(&mut rng);
        let net = &cluster.net;
        let n = cluster.len();
        for i in 0..n {
            assert!(net.bandwidth(i, i).is_infinite(), "case {case}: self bw");
            assert_eq!(net.latency(i, i), 0.0, "case {case}: self latency");
            assert_eq!(net.transfer_time(64.0, i, i), 0.0, "case {case}");
            for j in 0..n {
                assert_eq!(
                    net.bandwidth(i, j).to_bits(),
                    net.bandwidth(j, i).to_bits(),
                    "case {case}: bw({i},{j}) asymmetric"
                );
                assert_eq!(
                    net.latency(i, j).to_bits(),
                    net.latency(j, i).to_bits(),
                    "case {case}: lat({i},{j}) asymmetric"
                );
            }
        }
        // Rack-local links dominate cross-rack ones in both coordinates.
        let mut min_local_bw = f64::INFINITY;
        let mut max_local_lat = 0.0f64;
        let mut max_cross_bw = 0.0f64;
        let mut min_cross_lat = f64::INFINITY;
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                if cluster.same_rack(i, j) {
                    min_local_bw = min_local_bw.min(net.bandwidth(i, j));
                    max_local_lat = max_local_lat.max(net.latency(i, j));
                } else {
                    max_cross_bw = max_cross_bw.max(net.bandwidth(i, j));
                    min_cross_lat = min_cross_lat.min(net.latency(i, j));
                }
            }
        }
        if max_cross_bw > 0.0 && min_local_bw.is_finite() {
            assert!(
                min_local_bw >= max_cross_bw,
                "case {case}: local bw {min_local_bw} < cross bw {max_cross_bw}"
            );
            assert!(
                max_local_lat <= min_cross_lat,
                "case {case}: local lat {max_local_lat} > cross lat {min_cross_lat}"
            );
        }
        // c̄ stays a usable normalizer on every topology.
        assert!(
            cluster.c_avg().is_finite() && cluster.c_avg() > 0.0,
            "case {case}: c_avg {}",
            cluster.c_avg()
        );
    }
}

/// Every scheduler still produces `validate()`-clean schedules on
/// rack-structured clusters, and flat transfer pricing stays bitwise the
/// scalar formula on random inputs.
#[test]
fn prop_schedulers_valid_on_topologies() {
    for case in 0..CASES {
        let mut rng = Rng::new(12_000 + case);
        let w = random_workload(&mut rng, rng.range_u(1, 4), case % 2 == 0);
        let cluster = random_net_cluster(&mut rng);
        let comm = cluster.comm_mbps;
        if cluster.net.is_flat() {
            for _ in 0..8 {
                let (d, i, j) = (
                    rng.range_f(0.1, 500.0),
                    rng.below(cluster.len()),
                    rng.below(cluster.len()),
                );
                let want = if i == j { 0.0 } else { d / comm };
                assert_eq!(
                    cluster.transfer_time(d, i, j).to_bits(),
                    want.to_bits(),
                    "case {case}: flat pricing drifted"
                );
            }
        }
        let mut scheds: Vec<Box<dyn Scheduler>> = vec![
            Box::new(HeftScheduler::new()),
            Box::new(HighRankUpScheduler::new()),
            Box::new(TdcaScheduler::new()),
        ];
        for sched in scheds.iter_mut() {
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            let report = sim
                .run(sched.as_mut())
                .unwrap_or_else(|e| panic!("case {case} {}: {e}", sched.name()));
            assert!(report.makespan.is_finite() && report.makespan > 0.0);
            sim.state
                .validate()
                .unwrap_or_else(|e| panic!("case {case} {}: {e}", sched.name()));
        }
    }
}

/// The CSR representation must round-trip to the dense adjacency and job
/// membership matrices exactly (independently reconstructed from the DAG
/// and the slot mapping).
#[test]
fn prop_csr_roundtrips_dense() {
    use lachesis::policy::encode::encode;
    use lachesis::policy::features::FeatureMode;
    for case in 0..CASES {
        let mut rng = Rng::new(6300 + case);
        let n_jobs = 1 + (case as usize % 10);
        let w = random_workload(&mut rng, n_jobs, false);
        let cluster = random_cluster(&mut rng);
        let mut st = SimState::new(cluster, w);
        for j in 0..st.jobs.len() {
            st.mark_arrived(j);
        }
        for _ in 0..4 {
            let enc = encode(&st, FeatureMode::Full);
            let n = enc.variant.n;
            // Dense adjacency reconstructed from the DAG + slot mapping.
            let mut want_adj = vec![0.0f32; n * n];
            for i in 0..enc.n_used() {
                let t = enc.slot_task(i).unwrap();
                for e in &st.jobs[t.job].children[t.node] {
                    if let Some(ci) = enc.task_slot(TaskRef::new(t.job, e.other)) {
                        want_adj[i * n + ci] = 1.0;
                    }
                }
            }
            assert_eq!(enc.dense_adj(), want_adj, "case {case}: adjacency");
            // Dense job membership: job slots in order of first appearance.
            let mut want_job = vec![0.0f32; enc.variant.j * n];
            let mut job_slot: std::collections::BTreeMap<usize, usize> = Default::default();
            for i in 0..enc.n_used() {
                let t = enc.slot_task(i).unwrap();
                let next = job_slot.len();
                let js = *job_slot.entry(t.job).or_insert(next);
                want_job[js * n + i] = 1.0;
            }
            assert_eq!(enc.dense_jobmat(), want_job, "case {case}: jobmat");
            if st.executable().is_empty() {
                break;
            }
            let t = st.executable()[rng.below(st.executable().len())];
            let exec = rng.below(st.cluster.len());
            st.apply(t, Allocation::Direct { exec });
        }
    }
}
