//! Cross-layer integration: the AOT artifact executed through PJRT must
//! agree with the pure-rust reference forward on real encoded states —
//! this is the proof that L1 (Pallas kernel), L2 (JAX model) and the rust
//! model contract all describe the same network.
//!
//! Requires `make artifacts` to have run (the Makefile test target
//! guarantees it) and the `pjrt` cargo feature; without the feature this
//! whole test target compiles to nothing.
#![cfg(feature = "pjrt")]

use lachesis::cluster::Cluster;
use lachesis::config::{ClusterConfig, WorkloadConfig};
use lachesis::policy::encode::encode;
use lachesis::policy::features::FeatureMode;
use lachesis::policy::{params, PolicyEval, RustPolicy};
use lachesis::runtime::{PjrtPolicy, Runtime};
use lachesis::sim::{Allocation, SimState};
use lachesis::workload::WorkloadGenerator;

const ART: &str = "artifacts";

fn artifacts_available() -> bool {
    std::path::Path::new(&format!("{ART}/meta.json")).exists()
}

fn make_state(n_jobs: usize, seed: u64, big: bool) -> SimState {
    let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(8), seed);
    let cfg = if big {
        WorkloadConfig::large_batch(n_jobs)
    } else {
        WorkloadConfig::small_batch(n_jobs)
    };
    let w = WorkloadGenerator::new(cfg, seed).generate();
    let mut st = SimState::new(cluster, w);
    for j in 0..n_jobs {
        st.mark_arrived(j);
    }
    st
}

#[test]
fn meta_matches_rust_contract() {
    if !artifacts_available() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let rt = Runtime::new(ART).unwrap();
    assert_eq!(rt.meta.param_len, lachesis::policy::net::param_len());
    assert_eq!(rt.meta.f, lachesis::policy::F);
    assert_eq!(rt.meta.variants.len(), 2);
}

#[test]
fn pjrt_and_rust_forward_agree_small_variant() {
    if !artifacts_available() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let p = params::load_expected(
        &format!("{ART}/params_init.bin"),
        lachesis::policy::net::param_len(),
    )
    .unwrap();
    let mut pjrt = PjrtPolicy::with_params(ART, p.clone()).unwrap();
    let mut rust = RustPolicy::new(p);
    for seed in 0..5u64 {
        let st = make_state(2, seed, false);
        let enc = encode(&st, FeatureMode::Full);
        assert_eq!(enc.variant.n, 64);
        let (lp, vp) = pjrt.logits_value(&enc).unwrap();
        let (lr, vr) = rust.logits_value(&enc).unwrap();
        for i in 0..enc.n_used() {
            assert!(
                (lp[i] - lr[i]).abs() < 1e-4,
                "seed {seed} slot {i}: pjrt {} vs rust {}",
                lp[i],
                lr[i]
            );
        }
        assert!((vp - vr).abs() < 1e-4, "value: {vp} vs {vr}");
    }
}

#[test]
fn pjrt_and_rust_forward_agree_large_variant() {
    if !artifacts_available() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let p = params::load_expected(
        &format!("{ART}/params_init.bin"),
        lachesis::policy::net::param_len(),
    )
    .unwrap();
    let mut pjrt = PjrtPolicy::with_params(ART, p.clone()).unwrap();
    let mut rust = RustPolicy::new(p);
    let st = make_state(12, 3, false);
    let enc = encode(&st, FeatureMode::Full);
    assert_eq!(enc.variant.n, 256, "12 jobs should spill into the big variant");
    let (lp, vp) = pjrt.logits_value(&enc).unwrap();
    let (lr, vr) = rust.logits_value(&enc).unwrap();
    for i in 0..enc.n_used() {
        assert!((lp[i] - lr[i]).abs() < 1e-4, "slot {i}");
    }
    assert!((vp - vr).abs() < 1e-4);
}

#[test]
fn agreement_holds_mid_schedule() {
    if !artifacts_available() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let p = params::load_expected(
        &format!("{ART}/params_init.bin"),
        lachesis::policy::net::param_len(),
    )
    .unwrap();
    let mut pjrt = PjrtPolicy::with_params(ART, p.clone()).unwrap();
    let mut rust = RustPolicy::new(p);
    let mut st = make_state(2, 9, false);
    // Assign half the frontier greedily, re-checking agreement each step.
    for step in 0..6 {
        if st.executable().is_empty() {
            break;
        }
        let enc = encode(&st, FeatureMode::Full);
        let (lp, _) = pjrt.logits_value(&enc).unwrap();
        let (lr, _) = rust.logits_value(&enc).unwrap();
        for i in 0..enc.n_used() {
            assert!((lp[i] - lr[i]).abs() < 1e-4, "step {step} slot {i}");
        }
        let t = st.executable()[0];
        st.apply(t, Allocation::Direct { exec: step % 8 });
    }
}

#[test]
fn lachesis_via_pjrt_schedules_end_to_end() {
    if !artifacts_available() {
        eprintln!("skipping: no artifacts");
        return;
    }
    use lachesis::sched::LachesisScheduler;
    use lachesis::sim::Simulator;
    let pjrt = PjrtPolicy::new(ART, None).unwrap();
    let mut sched = LachesisScheduler::greedy(Box::new(pjrt));
    let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(10), 4);
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(4), 4).generate();
    let mut sim = Simulator::new(cluster, w);
    let report = sim.run(&mut sched).unwrap();
    assert!(report.makespan > 0.0);
    sim.state.validate().unwrap();
    // Median decision latency should be small even in debug builds (the
    // p98 includes the first-call XLA compilation; the release benches in
    // rust/benches/ measure the steady state the paper reports).
    assert!(
        report.decision_ms.percentile(50.0) < 50.0,
        "p50 = {} ms",
        report.decision_ms.percentile(50.0)
    );
}

#[test]
fn rejects_stale_params_file() {
    if !artifacts_available() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let path = "/tmp/lachesis_stale_params.bin";
    params::save_f32(path, &[0.0; 7]).unwrap();
    assert!(PjrtPolicy::new(ART, Some(path)).is_err());
    std::fs::remove_file(path).ok();
}
