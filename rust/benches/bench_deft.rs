//! Hot-path microbenchmarks of the phase-2 allocators: EFT vs CPEFT vs
//! full DEFT across executor counts (the O(P·M) loop of §5.1).

use lachesis::bench_util::{black_box, Bench};
use lachesis::cluster::Cluster;
use lachesis::config::{ClusterConfig, WorkloadConfig};
use lachesis::sched::deft::{cpeft, deft};
use lachesis::sched::eft::best_eft;
use lachesis::sim::{Allocation, SimState};
use lachesis::workload::WorkloadGenerator;

fn mid_schedule_state(executors: usize, jobs: usize) -> SimState {
    let cluster = Cluster::heterogeneous(&ClusterConfig::with_executors(executors), 1);
    let w = WorkloadGenerator::new(WorkloadConfig::large_batch(jobs), 1).generate();
    let mut st = SimState::new(cluster, w);
    for j in 0..jobs {
        st.mark_arrived(j);
    }
    // Assign half the tasks so allocators see realistic placements.
    let half = st.n_tasks_total() / 2;
    for i in 0..half {
        if st.executable().is_empty() {
            break;
        }
        let t = st.executable()[0];
        st.apply(t, Allocation::Direct { exec: i % executors });
    }
    st
}

fn main() {
    let mut b = Bench::new();
    for &execs in &[10, 50, 200] {
        let st = mid_schedule_state(execs, 8);
        let t = st.executable()[st.executable().len() / 2];
        b.case(&format!("best_eft/{execs}exec"), || {
            black_box(best_eft(&st, black_box(t)));
        });
        if let Some(edge) = st.jobs[t.job].parents[t.node].first() {
            let parent = edge.other;
            b.case(&format!("cpeft_single/{execs}exec"), || {
                black_box(cpeft(&st, black_box(t), parent, 0));
            });
        }
        b.case(&format!("deft_full/{execs}exec"), || {
            black_box(deft(&st, black_box(t)));
        });
    }
    b.finish("bench_deft");
}
