//! Whole-simulation throughput: events/second and full-schedule wall time
//! for each scheduler family at paper scales.

use lachesis::bench_util::{black_box, Bench};
use lachesis::cluster::Cluster;
use lachesis::config::{ClusterConfig, WorkloadConfig};
use lachesis::policy::RustPolicy;
use lachesis::sched::{
    FifoScheduler, HeftScheduler, HighRankUpScheduler, LachesisScheduler, TdcaScheduler,
};
use lachesis::sim::Simulator;
use lachesis::workload::WorkloadGenerator;

fn main() {
    let mut b = Bench::new();
    let cfg = ClusterConfig::default();

    for &(jobs, tag) in &[(5usize, "small5"), (20, "batch20"), (50, "batch50")] {
        let w = WorkloadGenerator::new(WorkloadConfig::large_batch(jobs), 2).generate();
        let cluster = Cluster::heterogeneous(&cfg, 2);
        b.case(&format!("sim_heft/{tag}"), || {
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            black_box(sim.run(&mut HeftScheduler::new()).unwrap());
        });
        b.case(&format!("sim_rankup_deft/{tag}"), || {
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            black_box(sim.run(&mut HighRankUpScheduler::new()).unwrap());
        });
        b.case(&format!("sim_fifo_deft/{tag}"), || {
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            black_box(sim.run(&mut FifoScheduler::new()).unwrap());
        });
        b.case(&format!("sim_tdca/{tag}"), || {
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            black_box(sim.run(&mut TdcaScheduler::new()).unwrap());
        });
    }
    // Learned policy (rust backend) at moderate scale.
    let w = WorkloadGenerator::new(WorkloadConfig::large_batch(20), 3).generate();
    let cluster = Cluster::heterogeneous(&cfg, 3);
    b.case("sim_lachesis_rust/batch20", || {
        let mut sched = LachesisScheduler::greedy(Box::new(RustPolicy::random(1)));
        let mut sim = Simulator::new(cluster.clone(), w.clone());
        black_box(sim.run(&mut sched).unwrap());
    });
    b.finish("bench_sim");
}
