//! Whole-simulation throughput: events/second and full-schedule wall time
//! for each scheduler family at paper scales, plus the gap-aware vs
//! append makespan comparison. Writes `BENCH_sim.json` (override with
//! `BENCH_JSON`) so future PRs have a perf trajectory to compare against.

use lachesis::bench_util::{black_box, Bench};
use lachesis::cluster::Cluster;
use lachesis::config::{ClusterConfig, SchedMode, WorkloadConfig};
use lachesis::policy::RustPolicy;
use lachesis::sched::{
    FifoScheduler, HeftScheduler, HighRankUpScheduler, LachesisScheduler, SjfScheduler,
    TdcaScheduler,
};
use lachesis::sim::Simulator;
use lachesis::workload::WorkloadGenerator;
use std::time::Instant;

fn main() {
    let mut b = Bench::new();
    let cfg = ClusterConfig::default();

    for &(jobs, tag) in &[(5usize, "small5"), (20, "batch20"), (50, "batch50")] {
        let w = WorkloadGenerator::new(WorkloadConfig::large_batch(jobs), 2).generate();
        let cluster = Cluster::heterogeneous(&cfg, 2);
        b.case(&format!("sim_heft/{tag}"), || {
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            black_box(sim.run(&mut HeftScheduler::new()).unwrap());
        });
        b.case(&format!("sim_rankup_deft/{tag}"), || {
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            black_box(sim.run(&mut HighRankUpScheduler::new()).unwrap());
        });
        b.case(&format!("sim_fifo_deft/{tag}"), || {
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            black_box(sim.run(&mut FifoScheduler::new()).unwrap());
        });
        // SJF leans hardest on the per-job remaining-work cache (its score
        // probes job_left_work for every executable task of every
        // decision) — the headline case for the incremental SimState.
        b.case(&format!("sim_sjf_deft/{tag}"), || {
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            black_box(sim.run(&mut SjfScheduler::new()).unwrap());
        });
        b.case(&format!("sim_tdca/{tag}"), || {
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            black_box(sim.run(&mut TdcaScheduler::new()).unwrap());
        });
    }

    // Decision throughput at the batch50 scale: scheduling decisions per
    // second of wall time across a full run (the ≥2× acceptance metric).
    {
        let w = WorkloadGenerator::new(WorkloadConfig::large_batch(50), 2).generate();
        let cluster = Cluster::heterogeneous(&cfg, 2);
        let mut decisions = 0u64;
        let mut secs = 0.0f64;
        for _ in 0..3 {
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            let t0 = Instant::now();
            let r = sim.run(&mut HeftScheduler::new()).unwrap();
            secs += t0.elapsed().as_secs_f64();
            decisions += r.n_tasks as u64;
        }
        b.note("decision_throughput_heft_batch50_per_sec", decisions as f64 / secs);
        let mut decisions = 0u64;
        let mut secs = 0.0f64;
        for _ in 0..3 {
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            let t0 = Instant::now();
            let r = sim.run(&mut SjfScheduler::new()).unwrap();
            secs += t0.elapsed().as_secs_f64();
            decisions += r.n_tasks as u64;
        }
        b.note("decision_throughput_sjf_batch50_per_sec", decisions as f64 / secs);
    }

    // Gap-aware vs append EFT: same workloads, same HEFT scheduler, only
    // the booking mode differs. Gap-aware backfilling should never lose.
    {
        let mut gap_cfg = cfg.clone();
        gap_cfg.sched_mode = SchedMode::GapAware;
        let mut append_total = 0.0;
        let mut gap_total = 0.0;
        for seed in 0..5u64 {
            let w = WorkloadGenerator::new(WorkloadConfig::large_batch(30), seed).generate();
            let append_ms = Simulator::new(Cluster::heterogeneous(&cfg, seed), w.clone())
                .run(&mut HeftScheduler::new())
                .unwrap()
                .makespan;
            let gap_ms = Simulator::new(Cluster::heterogeneous(&gap_cfg, seed), w)
                .run(&mut HeftScheduler::new())
                .unwrap()
                .makespan;
            append_total += append_ms;
            gap_total += gap_ms;
            b.note(&format!("makespan_heft_append_seed{seed}"), append_ms);
            b.note(&format!("makespan_heft_gap_seed{seed}"), gap_ms);
        }
        b.note("makespan_gap_over_append_ratio", gap_total / append_total);
    }

    // Fault-subsystem overhead: a zero-fault plan must be invisible —
    // the acceptance gate is < 5% vs no plan at all. The two variants
    // are interleaved iteration by iteration so runner noise and thermal
    // drift hit both sides equally (separately-measured cases would make
    // the ratio a coin flip at small budgets).
    {
        use lachesis::config::FaultConfig;
        use lachesis::fault::FaultPlan;
        let w = WorkloadGenerator::new(WorkloadConfig::large_batch(20), 4).generate();
        let cluster = Cluster::heterogeneous(&cfg, 4);
        let none = FaultPlan::none();
        let t0 = Instant::now();
        {
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            black_box(sim.run(&mut HeftScheduler::new()).unwrap());
        }
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        // Floor of 50 interleaved pairs: the CI gate on this ratio is
        // hard, so short-sample variance must not dominate even when
        // BENCH_BUDGET_SECS is tiny.
        let iters = ((b.budget_secs / once).ceil() as usize).clamp(50, 10_000);
        let (mut t_plain, mut t_fault) = (0.0f64, 0.0f64);
        for _ in 0..iters {
            let t = Instant::now();
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            black_box(sim.run(&mut HeftScheduler::new()).unwrap());
            t_plain += t.elapsed().as_secs_f64();
            let t = Instant::now();
            let mut sim = Simulator::with_faults(cluster.clone(), w.clone(), &none);
            black_box(sim.run(&mut HeftScheduler::new()).unwrap());
            t_fault += t.elapsed().as_secs_f64();
        }
        b.note("fault_overhead_ratio", t_fault / t_plain);

        // A live-fault run for the perf trajectory: recovery passes,
        // blackout booking and rescheduling included.
        let plan = FaultPlan::generate(&FaultConfig::with_rate(1e-3), cluster.len(), 4);
        b.case("sim_heft_faulty_1e-3/batch20", || {
            let mut sim = Simulator::with_faults(cluster.clone(), w.clone(), &plan);
            black_box(sim.run(&mut HeftScheduler::new()).unwrap());
        });
    }

    // Disabled-telemetry overhead: with the master switch off, every
    // obs probe on the sim/policy hot path must collapse to a relaxed
    // atomic load and a predictable branch. The probes cannot be
    // compiled out at runtime, so no probe-free A/B build exists to
    // time against; instead measure the per-decision probe cost
    // directly — a bundle deliberately over-provisioned vs the real
    // site count (6 disabled spans + 4 gate checks, where a HEFT
    // decision executes 4 spans and 3 checks) — multiply by the
    // decisions a run makes, and report
    //   t_run / (t_run - n_decisions * t_bundle)
    // i.e. run time relative to a hypothetical probe-free build. CI
    // gates this below 1.03.
    {
        lachesis::obs::set_enabled(false);
        let w = WorkloadGenerator::new(WorkloadConfig::large_batch(20), 7).generate();
        let cluster = Cluster::heterogeneous(&cfg, 7);
        let bundle = || {
            for _ in 0..6 {
                black_box(lachesis::obs::trace::span("bench", "probe"));
            }
            for _ in 0..4 {
                black_box(lachesis::obs::enabled());
            }
        };
        let probe_iters = 200_000usize;
        let t0 = Instant::now();
        for _ in 0..probe_iters {
            bundle();
        }
        let t_bundle = t0.elapsed().as_secs_f64() / probe_iters as f64;
        let t0 = Instant::now();
        {
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            black_box(sim.run(&mut HeftScheduler::new()).unwrap());
        }
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        // Hard CI gate on the ratio: enough runs that short-sample
        // variance cannot dominate at tiny budgets.
        let iters = ((b.budget_secs * 0.1 / once).ceil() as usize).clamp(20, 2_000);
        let (mut t_run, mut decisions) = (0.0f64, 0u64);
        for _ in 0..iters {
            let t = Instant::now();
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            let r = sim.run(&mut HeftScheduler::new()).unwrap();
            t_run += t.elapsed().as_secs_f64();
            decisions += r.n_tasks as u64;
        }
        let probe_cost = decisions as f64 * t_bundle;
        // Clamp the denominator: if the probe estimate ever exceeded
        // half the run (it is orders of magnitude below), report a
        // loud 2.0 rather than a nonsense negative ratio.
        let ratio = t_run / (t_run - probe_cost).max(t_run * 0.5);
        b.note("obs_disabled_overhead_ratio", ratio);
    }

    // Network-model overhead: under `flat` the matrix-backed
    // `transfer_time` must price exactly like the old scalar division,
    // and the CI gate holds its cost to < 5% over the inline formula.
    // Interleaved like the fault gate so runner noise hits both sides.
    {
        let cluster = Cluster::heterogeneous(&cfg, 5);
        let comm = cfg.comm_mbps;
        let n = cluster.len();
        let pairs: Vec<(usize, usize)> =
            (0..n).flat_map(|i| (0..n).map(move |j| (i, j))).collect();
        let lookup_pass = |cluster: &Cluster| {
            let mut acc = 0.0f64;
            for &(i, j) in &pairs {
                acc += cluster.transfer_time(black_box(64.0), i, j);
            }
            black_box(acc)
        };
        let scalar_pass = || {
            let mut acc = 0.0f64;
            for &(i, j) in &pairs {
                // The pre-topology model: free on-executor, data/c̄ else.
                acc += if i == j {
                    0.0
                } else {
                    black_box(64.0) / black_box(comm)
                };
            }
            black_box(acc)
        };
        let t0 = Instant::now();
        lookup_pass(&cluster);
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        // Hard CI gate on the ratio: keep enough interleaved pairs that
        // short-sample variance cannot dominate at tiny budgets.
        let iters = ((b.budget_secs * 0.1 / once).ceil() as usize).clamp(500, 500_000);
        let (mut t_net, mut t_scalar) = (0.0f64, 0.0f64);
        for _ in 0..iters {
            let t = Instant::now();
            lookup_pass(&cluster);
            t_net += t.elapsed().as_secs_f64();
            let t = Instant::now();
            scalar_pass();
            t_scalar += t.elapsed().as_secs_f64();
        }
        b.note("net_flat_overhead_ratio", t_net / t_scalar);
    }

    // A rack-structured run for the perf trajectory: same workload scale
    // as the flat batch20 cases, with tree-topology transfer pricing.
    {
        let mut tree_cfg = cfg.clone();
        tree_cfg.net = lachesis::net::NetConfig::tree(5, 10);
        let w = WorkloadGenerator::new(WorkloadConfig::large_batch(20), 6).generate();
        let cluster = Cluster::heterogeneous(&tree_cfg, 6);
        b.case("sim_heft_tree5x10/batch20", || {
            let mut sim = Simulator::new(cluster.clone(), w.clone());
            black_box(sim.run(&mut HeftScheduler::new()).unwrap());
        });
    }

    // Learned policy (rust backend) at moderate scale.
    let w = WorkloadGenerator::new(WorkloadConfig::large_batch(20), 3).generate();
    let cluster = Cluster::heterogeneous(&cfg, 3);
    b.case("sim_lachesis_rust/batch20", || {
        let mut sched = LachesisScheduler::greedy(Box::new(RustPolicy::random(1)));
        let mut sim = Simulator::new(cluster.clone(), w.clone());
        black_box(sim.run(&mut sched).unwrap());
    });
    b.finish("bench_sim");
    if std::env::var("BENCH_JSON").is_err() {
        // Cargo runs benches with cwd = the package dir (rust/); anchor
        // the default report next to the repo-root placeholder instead.
        b.write_json(
            "bench_sim",
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sim.json"),
        );
    }
}
