//! Figure-regeneration benchmark: runs the quick variants of every figure
//! sweep end-to-end (the same code path as `lachesis repro ...`) and
//! reports their wall time, sequentially and with the sweep cells fanned
//! out over worker threads. Keeping the full experiment harness inside
//! `cargo bench` guarantees the reproduction pipeline never bit-rots.

use lachesis::bench_util::Bench;
use lachesis::exp::{self, PolicySource};

fn main() {
    let mut b = Bench::new();
    // Quick sweeps use the rust policy backend (no artifact dependency) so
    // `cargo bench` works on a bare checkout; the `repro` CLI uses PJRT.
    let src = PolicySource {
        backend: "rust".into(),
        ..Default::default()
    };
    b.case("fig5_quick_sweep", || {
        exp::fig5(&src, true, 1, 1).unwrap();
    });
    b.case("fig6_quick_sweep", || {
        exp::fig6(&src, true, 1, 1).unwrap();
    });
    b.case("fig7_quick_sweep", || {
        exp::fig7(&src, true, 1, 1).unwrap();
    });
    // The same fig6 sweep with parallel cells — the speedup over
    // fig6_quick_sweep is the scaling headroom of the harness.
    let threads = std::thread::available_parallelism()
        .map(|n| n.get().min(4))
        .unwrap_or(1);
    b.case("fig6_quick_sweep_par", || {
        exp::fig6(&src, true, 1, threads).unwrap();
    });
    b.finish("bench_figures");
}
