//! End-to-end decision latency with the production (PJRT) policy: a full
//! Lachesis schedule at each paper scale, reporting per-decision p50/p98
//! — directly comparable to Figs 5d/6d/7b.

use lachesis::bench_util::Bench;
use lachesis::cluster::Cluster;
use lachesis::config::{ClusterConfig, WorkloadConfig};
use lachesis::policy::RustPolicy;
#[cfg(feature = "pjrt")]
use lachesis::runtime::PjrtPolicy;
use lachesis::sched::LachesisScheduler;
use lachesis::sim::Simulator;
use lachesis::workload::WorkloadGenerator;

fn run_once(jobs: usize, large: bool, pjrt: bool, seed: u64) -> (f64, f64) {
    let cfg = ClusterConfig::default();
    let wcfg = if large {
        WorkloadConfig::large_batch(jobs)
    } else {
        WorkloadConfig::small_batch(jobs)
    };
    let w = WorkloadGenerator::new(wcfg, seed).generate();
    let cluster = Cluster::heterogeneous(&cfg, seed);
    let mut sched = if pjrt {
        pjrt_sched()
    } else {
        LachesisScheduler::greedy(Box::new(RustPolicy::random(seed)))
    };
    let mut sim = Simulator::new(cluster, w);
    let r = sim.run(&mut sched).unwrap();
    (
        r.decision_ms.percentile(50.0),
        r.decision_ms.percentile(98.0),
    )
}

#[cfg(feature = "pjrt")]
fn pjrt_sched() -> LachesisScheduler {
    LachesisScheduler::greedy(Box::new(PjrtPolicy::new("artifacts", None).unwrap()))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_sched() -> LachesisScheduler {
    unreachable!("PJRT cases are skipped when built without --features pjrt")
}

fn main() {
    let mut b = Bench::new();
    let have_artifacts =
        cfg!(feature = "pjrt") && std::path::Path::new("artifacts/meta.json").exists();
    println!("== per-decision latency (paper targets: p98 ≤ 14 ms small, ≤ 30 ms large) ==");
    for &(jobs, large, tag) in &[(5usize, false, "small5"), (20, false, "small20"), (40, true, "large40")]
    {
        for &(pjrt, backend) in &[(false, "rust"), (true, "pjrt")] {
            if pjrt && !have_artifacts {
                continue;
            }
            // Warm once (XLA compile), then measure a fresh run.
            let _ = run_once(jobs, large, pjrt, 1);
            let (p50, p98) = run_once(jobs, large, pjrt, 2);
            println!("decision/{tag}/{backend}: p50 {p50:.3} ms   p98 {p98:.3} ms");
        }
    }
    // Wall time of whole end-to-end schedules via the bench harness.
    for &(jobs, large, tag) in &[(10usize, false, "small10"), (40, true, "large40")] {
        b.case(&format!("e2e_schedule_rust/{tag}"), || {
            let _ = run_once(jobs, large, false, 3);
        });
        if have_artifacts {
            b.case(&format!("e2e_schedule_pjrt/{tag}"), || {
                let _ = run_once(jobs, large, true, 3);
            });
        }
    }
    b.finish("bench_e2e");
}
