//! Policy inference latency — the paper's decision-time metric (Figs
//! 5d/6d/7b target: ≤14 ms small / ≤30 ms large / ≤38 ms continuous at
//! p98). Measures feature extraction, from-scratch vs cached-incremental
//! encoding, the CSR-sparse rust forward vs the dense oracle, and the
//! PJRT artifact, per shape variant.
//!
//! `BENCH_JSON=BENCH_policy.json cargo bench --bench bench_policy` writes
//! the machine-readable report CI uploads (same pattern as bench_sim →
//! `BENCH_sim.json`); the `notes` record the dense/sparse and
//! fresh/cached speedups side by side.

use lachesis::bench_util::{black_box, Bench};
use lachesis::cluster::Cluster;
use lachesis::config::{ClusterConfig, TrainConfig, WorkloadConfig};
use lachesis::policy::encode::{encode, EncodedState};
use lachesis::policy::features::{node_features, FeatureMode, NODE_FEATURES};
use lachesis::policy::{EncoderCache, PackedBatch, PolicyEval, RustPolicy};
use lachesis::rl::cpu_backend::{CpuTrainBackend, CPU_TRAIN_BATCH};
use lachesis::rl::trainer::{Row, TrainBackend, Trainer};
#[cfg(feature = "pjrt")]
use lachesis::runtime::PjrtPolicy;
use lachesis::sim::{Allocation, SimState};
use lachesis::workload::WorkloadGenerator;

fn state_seeded(jobs: usize, seed: u64) -> SimState {
    let cluster = Cluster::heterogeneous(&ClusterConfig::default(), seed);
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(jobs), seed).generate();
    let mut st = SimState::new(cluster, w);
    for j in 0..jobs {
        st.mark_arrived(j);
    }
    st
}

fn state(jobs: usize) -> SimState {
    state_seeded(jobs, 1)
}

/// Synthetic training rows over the given encodings (first executable
/// slot as the action, alternating advantages) — the train_step bench's
/// batch payload.
fn rows_for(encs: &[EncodedState], n: usize) -> Vec<Row> {
    encs.iter()
        .cycle()
        .take(n)
        .enumerate()
        .map(|(i, e)| Row {
            enc: e.clone(),
            action: e.exec_mask.iter().position(|&m| m > 0.0).unwrap_or(0) as i32,
            adv: if i % 2 == 0 { 1.0 } else { -0.7 },
            ret: 0.5,
        })
        .collect()
}

/// Per-decision encoding cost along an identical evolving episode: apply
/// one task (the sim's dirty-tracking log records what changed), then
/// produce the encoding — fresh `encode()` vs incremental cache refresh.
/// Both variants drive the exact same apply/wall sequence and reset to a
/// fresh episode clone when drained, so the measured difference is
/// precisely "full rebuild" vs "patch" on equal states.
fn bench_encode_loop(b: &mut Bench, name: &str, jobs: usize, cached: bool) {
    let template = state(jobs);
    let mut st = template.clone();
    let mut cache = EncoderCache::new(FeatureMode::Full);
    if cached {
        cache.refresh(&st);
    }
    b.case(name, move || {
        if st.executable().is_empty() {
            st = template.clone();
            cache.reset();
        } else {
            let t = st.executable()[0];
            let finish = st.apply(t, Allocation::Direct { exec: 0 });
            st.wall = st.wall.max(finish * 0.5); // monotone mid-flight wall
        }
        if cached {
            black_box(cache.refresh(&st));
        } else {
            black_box(encode(&st, FeatureMode::Full));
        }
    });
}

fn main() {
    let mut b = Bench::new();
    let small = state(3); // → N=64 variant
    let large = state(14); // → N=256 variant

    let t = small.executable()[0];
    let mut feat = [0.0f32; NODE_FEATURES];
    b.case("features/one_node", || {
        node_features(&small, black_box(t), FeatureMode::Full, &mut feat);
        black_box(&feat);
    });
    // From-scratch encode of the full initial state (the cache's rebuild
    // path — now CSR, so no N² adjacency is materialized).
    b.case("encode_initial/n64", || {
        black_box(encode(&small, FeatureMode::Full));
    });
    b.case("encode_initial/n256", || {
        black_box(encode(&large, FeatureMode::Full));
    });
    // Like-for-like per-decision comparison: identical apply/wall loops,
    // fresh rebuild vs incremental patch (the pair CI gates on).
    bench_encode_loop(&mut b, "encode/n64", 3, false);
    bench_encode_loop(&mut b, "encode/n256", 14, false);
    bench_encode_loop(&mut b, "encode_cached/n64", 3, true);
    bench_encode_loop(&mut b, "encode_cached/n256", 14, true);

    let enc64 = encode(&small, FeatureMode::Full);
    let enc256 = encode(&large, FeatureMode::Full);
    let mut rust = RustPolicy::random(1);
    let mut logits = Vec::new();
    // The production serving path: CSR-sparse message passing through the
    // PolicyEval trait, logits written into a reused buffer.
    b.case("forward_rust/n64", || {
        black_box(rust.logits_value_into(&enc64, &mut logits).unwrap());
        black_box(&logits);
    });
    b.case("forward_rust/n256", || {
        black_box(rust.logits_value_into(&enc256, &mut logits).unwrap());
        black_box(&logits);
    });
    // The raw sparse kernel (no trait indirection).
    b.case("forward_sparse/n64", || {
        black_box(rust.forward_into(&enc64, &mut logits));
        black_box(&logits);
    });
    b.case("forward_sparse/n256", || {
        black_box(rust.forward_into(&enc256, &mut logits));
        black_box(&logits);
    });
    // The dense oracle — what the old forward computed (and what the
    // PJRT artifact computes), kept as the comparison baseline.
    b.case("forward_dense/n64", || {
        black_box(rust.forward_dense(&enc64));
    });
    b.case("forward_dense/n256", || {
        black_box(rust.forward_dense(&enc256));
    });

    // Batched forward: B states through one block-CSR graph vs a loop of
    // per-state forwards over the same states. The batch case includes
    // the pack cost (that is what the training loop pays per step).
    let encs64: Vec<EncodedState> = (0..16)
        .map(|s| encode(&state_seeded(3, 1 + s), FeatureMode::Full))
        .collect();
    let encs256: Vec<EncodedState> = (0..8)
        .map(|s| encode(&state_seeded(14, 1 + s), FeatureMode::Full))
        .collect();
    let mut values = Vec::new();
    b.case("forward_single_loop/n64", || {
        for e in &encs64 {
            black_box(rust.forward_into(e, &mut logits));
        }
    });
    b.case("forward_batch/n64", || {
        let refs: Vec<&EncodedState> = encs64.iter().collect();
        let batch = PackedBatch::pack(&refs);
        rust.forward_batch(&batch, &mut logits, &mut values);
        black_box(&values);
    });
    b.case("forward_single_loop/n256", || {
        for e in &encs256 {
            black_box(rust.forward_into(e, &mut logits));
        }
    });
    b.case("forward_batch/n256", || {
        let refs: Vec<&EncodedState> = encs256.iter().collect();
        let batch = PackedBatch::pack(&refs);
        rust.forward_batch(&batch, &mut logits, &mut values);
        black_box(&values);
    });

    // One full gradient step through the native CPU backend: batched
    // forward tape + analytic backward + global-norm clip + Adam.
    let rows64 = rows_for(&encs64, 32);
    let rows256 = rows_for(&encs256, 8);
    let mut cpu = CpuTrainBackend::new(RustPolicy::random_params(2));
    b.case("train_step/n64", || {
        black_box(cpu.update(&rows64, 1e-3, 0.01, 0.5).unwrap());
    });
    b.case("train_step/n256", || {
        black_box(cpu.update(&rows256, 1e-3, 0.01, 0.5).unwrap());
    });

    // Side-by-side speedups for the JSON report (CI asserts sparse/cached
    // beat their dense/fresh counterparts).
    let mean = |b: &Bench, name: &str| {
        b.results
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.mean_ns)
            .unwrap_or(f64::NAN)
    };
    let speedup_fwd64 = mean(&b, "forward_dense/n64") / mean(&b, "forward_rust/n64");
    let speedup_fwd256 = mean(&b, "forward_dense/n256") / mean(&b, "forward_rust/n256");
    let speedup_enc64 = mean(&b, "encode/n64") / mean(&b, "encode_cached/n64");
    let speedup_enc256 = mean(&b, "encode/n256") / mean(&b, "encode_cached/n256");
    let speedup_batch64 = mean(&b, "forward_single_loop/n64") / mean(&b, "forward_batch/n64");
    let speedup_batch256 = mean(&b, "forward_single_loop/n256") / mean(&b, "forward_batch/n256");
    b.note("forward_sparse_speedup_n64", speedup_fwd64);
    b.note("forward_sparse_speedup_n256", speedup_fwd256);
    b.note("encode_cached_speedup_n64", speedup_enc64);
    b.note("encode_cached_speedup_n256", speedup_enc256);
    b.note("forward_batch_speedup_n64", speedup_batch64);
    b.note("forward_batch_speedup_n256", speedup_batch256);

    // Tiny end-to-end training-epoch A/B: sequential actors vs a worker
    // pool, same seeds (so identical trajectories — only wall-clock
    // differs). Recorded as notes, not CI-gated: single-core runners
    // legitimately see threaded ≈ sequential.
    let train_wallclock_ms = |threads: usize| -> f64 {
        let cfg = TrainConfig {
            episodes: 2,
            agents: 4,
            jobs_per_episode: 2,
            executors: 6,
            imitation_epochs: 0,
            threads,
            ..Default::default()
        };
        let backend = CpuTrainBackend::new(RustPolicy::random_params(7));
        let mut trainer = Trainer::new(cfg, backend, FeatureMode::Full);
        let t0 = std::time::Instant::now();
        trainer.train(CPU_TRAIN_BATCH).unwrap();
        t0.elapsed().as_secs_f64() * 1e3
    };
    b.note("train_epoch_wallclock_seq_ms", train_wallclock_ms(1));
    b.note("train_epoch_wallclock_threaded_ms", train_wallclock_ms(4));

    #[cfg(feature = "pjrt")]
    if std::path::Path::new("artifacts/meta.json").exists() {
        let mut pjrt = PjrtPolicy::new("artifacts", None).unwrap();
        // Warm both executables (compile happens once, off the hot path).
        pjrt.logits_value(&enc64).unwrap();
        pjrt.logits_value(&enc256).unwrap();
        b.case("forward_pjrt/n64", || {
            black_box(pjrt.logits_value(&enc64).unwrap());
        });
        b.case("forward_pjrt/n256", || {
            black_box(pjrt.logits_value(&enc256).unwrap());
        });
    } else {
        eprintln!("(artifacts missing — skipping PJRT cases)");
    }
    #[cfg(not(feature = "pjrt"))]
    eprintln!("(built without `pjrt` — skipping PJRT cases)");
    b.finish("bench_policy");
}
