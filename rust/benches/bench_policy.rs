//! Policy inference latency — the paper's decision-time metric (Figs
//! 5d/6d/7b target: ≤14 ms small / ≤30 ms large / ≤38 ms continuous at
//! p98). Measures feature extraction, encoding, the pure-rust forward and
//! the PJRT artifact, per shape variant.

use lachesis::bench_util::{black_box, Bench};
use lachesis::cluster::Cluster;
use lachesis::config::{ClusterConfig, WorkloadConfig};
use lachesis::policy::encode::encode;
use lachesis::policy::features::{node_features, FeatureMode, NODE_FEATURES};
use lachesis::policy::{PolicyEval, RustPolicy};
#[cfg(feature = "pjrt")]
use lachesis::runtime::PjrtPolicy;
use lachesis::sim::SimState;
use lachesis::workload::WorkloadGenerator;

fn state(jobs: usize) -> SimState {
    let cluster = Cluster::heterogeneous(&ClusterConfig::default(), 1);
    let w = WorkloadGenerator::new(WorkloadConfig::small_batch(jobs), 1).generate();
    let mut st = SimState::new(cluster, w);
    for j in 0..jobs {
        st.mark_arrived(j);
    }
    st
}

fn main() {
    let mut b = Bench::new();
    let small = state(3); // → N=64 variant
    let large = state(14); // → N=256 variant

    let t = small.executable()[0];
    let mut feat = [0.0f32; NODE_FEATURES];
    b.case("features/one_node", || {
        node_features(&small, black_box(t), FeatureMode::Full, &mut feat);
        black_box(&feat);
    });
    b.case("encode/n64", || {
        black_box(encode(&small, FeatureMode::Full));
    });
    b.case("encode/n256", || {
        black_box(encode(&large, FeatureMode::Full));
    });

    let enc64 = encode(&small, FeatureMode::Full);
    let enc256 = encode(&large, FeatureMode::Full);
    let mut rust = RustPolicy::random(1);
    b.case("forward_rust/n64", || {
        black_box(rust.logits_value(&enc64).unwrap());
    });
    b.case("forward_rust/n256", || {
        black_box(rust.logits_value(&enc256).unwrap());
    });

    #[cfg(feature = "pjrt")]
    if std::path::Path::new("artifacts/meta.json").exists() {
        let mut pjrt = PjrtPolicy::new("artifacts", None).unwrap();
        // Warm both executables (compile happens once, off the hot path).
        pjrt.logits_value(&enc64).unwrap();
        pjrt.logits_value(&enc256).unwrap();
        b.case("forward_pjrt/n64", || {
            black_box(pjrt.logits_value(&enc64).unwrap());
        });
        b.case("forward_pjrt/n256", || {
            black_box(pjrt.logits_value(&enc256).unwrap());
        });
    } else {
        eprintln!("(artifacts missing — skipping PJRT cases)");
    }
    #[cfg(not(feature = "pjrt"))]
    eprintln!("(built without `pjrt` — skipping PJRT cases)");
    b.finish("bench_policy");
}
